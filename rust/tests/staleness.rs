//! Bounded-staleness suite: quorum barriers, straggler parking, and
//! late-reply folding (ISSUE 10).
//!
//! Three contracts under test. *Barrier equivalence*: the default
//! config, an explicit full-quorum policy, and an env-staged full
//! quorum must all reproduce the frozen hard-barrier trajectory
//! bit-for-bit — the staleness machinery must be invisible until a
//! fractional quorum is requested. *Executor agreement*: under a fixed
//! transient-slowdown plan and a fractional quorum, both transports
//! must produce identical trajectories *and* identical staleness logs —
//! membership is decided on modeled time, never wall-clock. *Resume
//! exactness*: a checkpoint taken with replies still parked must resume
//! into the uninterrupted run's exact trajectory, late folds included.
//!
//! Staging a `Trainer` reads `SODDA_STALENESS` (the rust-async CI lane
//! exports it process-wide), so every test serializes on the crate-wide
//! `util::env` lock and the ones that need a specific environment swap
//! the knob under a `ScopedEnv`. Explicit `.staleness(...)` pins win
//! over the environment either way.

use std::sync::MutexGuard;

use sodda::config::ExecutorKind;
use sodda::metrics::History;
use sodda::util::json::Value;
use sodda::{
    ExperimentConfig, ExperimentConfigBuilder, FaultPlan, RunState, StalenessPolicy, Trainer,
};

fn locked() -> MutexGuard<'static, ()> {
    sodda::util::env::lock()
}

/// Run `f` with `SODDA_STALENESS` set to `value` (unset for `None`),
/// holding the process-wide env lock for the scope.
fn with_staleness_env(value: Option<&str>, f: impl FnOnce()) {
    let _env = sodda::util::env::ScopedEnv::new().with(StalenessPolicy::ENV, value);
    f();
}

/// The suite's one fractional policy: a 0.75 quorum (5 of 6 replies on
/// the 3×2 grid), two iterations of tolerated staleness, and a 4×
/// straggler deadline.
fn quorum() -> StalenessPolicy {
    StalenessPolicy { quorum_frac: 0.75, max_staleness_iters: 2, timeout_factor: 4.0 }
}

fn base(n: usize, m: usize, p: usize, q: usize, iters: usize) -> ExperimentConfigBuilder {
    ExperimentConfig::builder()
        .name("staleness-suite")
        .dense(n, m)
        .grid(p, q)
        .inner_steps(8)
        .outer_iters(iters)
        .eval_every(1)
        .seed(13)
}

/// Everything trajectory equality means, minus `wall_s`.
fn assert_same_trajectory(a: &History, b: &History, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count diverged");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.iter, y.iter, "{label}: record cadence diverged");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{label}: loss at iter {}", x.iter);
        assert_eq!(x.sim_s.to_bits(), y.sim_s.to_bits(), "{label}: sim_s at iter {}", x.iter);
        assert_eq!(x.comm_bytes, y.comm_bytes, "{label}: comm_bytes at iter {}", x.iter);
        assert_eq!(
            x.grad_coord_evals, y.grad_coord_evals,
            "{label}: grad_coord_evals at iter {}",
            x.iter
        );
    }
}

// ---- barrier equivalence ---------------------------------------------------

/// ISSUE 10 acceptance: the default policy and every full-quorum policy
/// route through the frozen barrier path — bit-for-bit, across
/// dense/CSR × even/ragged shapes on both executors.
#[test]
fn full_quorum_policies_keep_the_barrier_bit_for_bit() {
    with_staleness_env(None, || {
        let shapes: [(ExperimentConfigBuilder, &str); 4] = [
            (base(120, 24, 2, 2, 4), "dense even"),
            (base(97, 23, 3, 2, 4), "dense ragged"),
            (base(120, 24, 2, 2, 4).sparse(120, 24, 4), "csr even"),
            (base(85, 19, 2, 3, 4).sparse(85, 19, 5), "csr ragged"),
        ];
        for (b, shape) in shapes {
            for kind in [ExecutorKind::InProcess, ExecutorKind::Threaded] {
                let label = format!("{shape} on {kind}");
                let bare = Trainer::new(b.clone().executor(kind).build().unwrap())
                    .unwrap()
                    .run()
                    .unwrap();
                let full = StalenessPolicy {
                    quorum_frac: 1.0,
                    max_staleness_iters: 7,
                    timeout_factor: 99.0,
                };
                let policies = [StalenessPolicy::default(), full];
                for pol in policies {
                    let cfg = b.clone().executor(kind).staleness(pol).build().unwrap();
                    let out = Trainer::new(cfg).unwrap().run().unwrap();
                    let lb = format!("{label}, policy {pol}");
                    assert_eq!(bare.w, out.w, "{lb}: final iterate diverged");
                    assert_same_trajectory(&bare.history, &out.history, &lb);
                    assert_eq!(bare.comm_bytes, out.comm_bytes, "{lb}: wire accounting diverged");
                    assert_eq!(bare.comm_msgs, out.comm_msgs, "{lb}: message accounting diverged");
                    assert!(
                        out.history.staleness.is_empty(),
                        "{lb}: a barrier run must not log staleness records"
                    );
                }
            }
        }
    });
}

/// An env-staged full quorum is the barrier too, and a blank knob means
/// unset — the rust-async lane's export must not perturb pinned runs.
#[test]
fn env_full_quorum_is_still_the_barrier() {
    let cfg = |b: &ExperimentConfigBuilder| b.clone().build().unwrap();
    let b = base(90, 18, 3, 2, 3);
    let mut bare = None;
    with_staleness_env(None, || {
        bare = Some(Trainer::new(cfg(&b)).unwrap().run().unwrap());
    });
    let bare = bare.unwrap();
    with_staleness_env(Some("1.0:3:8"), || {
        let mut t = Trainer::new(cfg(&b)).unwrap();
        assert!(t.staleness().is_some_and(|p| p.is_barrier()));
        let out = t.run().unwrap();
        assert_eq!(bare.w, out.w, "env full quorum diverged from the barrier");
        assert_same_trajectory(&bare.history, &out.history, "env full quorum");
    });
    with_staleness_env(Some("   "), || {
        assert!(Trainer::new(cfg(&b)).unwrap().staleness().is_none(), "blank means unset");
    });
}

// ---- quorum behavior -------------------------------------------------------

/// One 4x-slowed worker under a 0.75 quorum on a 3×2 grid: the phase
/// releases at the 5th reply, the straggler's reply parks and folds
/// into the next iteration at half weight, and the whole thing is
/// cheaper on the simulated clock than the same plan under a barrier.
#[test]
fn quorum_parks_stragglers_and_undercuts_the_barrier_clock() {
    let _g = locked();
    let b = base(90, 18, 3, 2, 6);
    let plan: FaultPlan = "0@2:mu~slow:4,4@3:grad~slow:6,1@4:mu~slow:3".parse().unwrap();

    let pinned = b.clone().staleness(StalenessPolicy::default()).build().unwrap();
    let mut barrier = Trainer::new(pinned).unwrap();
    barrier.set_fault_plan(Some(plan.clone()));
    let slow = barrier.run().unwrap();
    assert!(slow.history.staleness.is_empty(), "the barrier must not log staleness");

    let mut t = Trainer::new(b.clone().staleness(quorum()).build().unwrap()).unwrap();
    t.set_fault_plan(Some(plan.clone()));
    let out = t.run().unwrap();

    let logs = &out.history.staleness;
    assert!(!logs.is_empty(), "the slowdowns must push workers past the quorum cut");
    let parked: usize = logs.iter().map(|r| r.late).sum();
    let folds: usize = logs.iter().map(|r| r.folds).sum();
    assert!(parked > 0, "no replies were parked");
    assert!(folds > 0, "parked replies never folded back in");
    assert!(
        logs.iter().all(|r| r.mu_quorum <= r.workers && r.grad_quorum <= r.workers),
        "a quorum cannot exceed the worker count"
    );
    let end = |o: &sodda::train::TrainOutcome| o.history.records.last().unwrap().sim_s;
    assert!(
        end(&out) < end(&slow),
        "quorum release must undercut the barrier under the same slowdowns: {} vs {}",
        end(&out),
        end(&slow)
    );

    // the staleness log survives the history's JSON round trip
    let v = Value::parse(&out.history.to_json().to_string_pretty()).unwrap();
    assert_eq!(History::from_json(&v).unwrap().staleness, *logs);
}

/// Both executors under the same fixed slowdown plan and fractional
/// quorum: identical trajectories, identical staleness logs. Membership
/// is decided on modeled time, so the threads' real scheduling must not
/// leak into the numbers.
#[test]
fn executors_agree_on_staleness_logs_under_a_fixed_slowdown_plan() {
    let _g = locked();
    let b = base(90, 18, 3, 2, 5);
    let plan: FaultPlan = "0@1:mu~slow:5,3@2:grad~slow:4,5@3:mu~slow:4".parse().unwrap();
    let run = |kind: ExecutorKind| {
        let cfg = b.clone().executor(kind).staleness(quorum()).build().unwrap();
        let mut t = Trainer::new(cfg).unwrap();
        t.set_fault_plan(Some(plan.clone()));
        t.run().unwrap()
    };
    let a = run(ExecutorKind::InProcess);
    let t = run(ExecutorKind::Threaded);
    assert_eq!(a.w, t.w, "final iterate diverged across executors");
    assert_same_trajectory(&a.history, &t.history, "cross-executor staleness");
    assert_eq!(a.comm_bytes, t.comm_bytes, "wire accounting diverged");
    assert_eq!(a.history.staleness, t.history.staleness, "staleness logs diverged");
    assert!(!a.history.staleness.is_empty(), "the plan never parked anything");
}

// ---- checkpoint / resume ---------------------------------------------------

/// Interrupt a quorum run at an iteration whose gradient stragglers are
/// still parked: the snapshot must carry them (`late_set`), and the
/// resumed session must fold them exactly where the uninterrupted run
/// does — trajectory bit-for-bit from there on.
#[test]
fn resume_with_a_non_empty_late_set_matches_the_uninterrupted_run() {
    let _g = locked();
    let b = base(90, 18, 3, 2, 6).staleness(quorum());
    let plan: FaultPlan = "2@3:grad~slow:5".parse().unwrap();
    let cfg = || b.clone().build().unwrap();

    let mut full = Trainer::new(cfg()).unwrap();
    full.set_fault_plan(Some(plan.clone()));
    let a = full.run().unwrap();

    let mut first = Trainer::new(cfg()).unwrap();
    first.set_fault_plan(Some(plan.clone()));
    // iteration 3 parks worker 2's gradient slice; it folds at t=4, so
    // interrupting right after step 4 (iterations 0..=3 done) snapshots
    // a live LateSet
    for _ in 0..4 {
        first.step().unwrap();
    }
    let snap = first.checkpoint();
    assert!(
        !snap.late.is_empty(),
        "the gradient slice parked at iteration 3 must be in the snapshot"
    );
    // through the serialized form — resuming from in-memory state would
    // not test the late_set encoding
    let text = snap.to_json().to_string_pretty();
    assert!(text.contains("late_set"));
    let snap = RunState::from_json(&Value::parse(&text).unwrap()).unwrap();
    let mut second = Trainer::resume(cfg(), snap).unwrap();
    second.set_fault_plan(Some(plan.clone()));
    let o = second.run().unwrap();

    assert_eq!(a.w, o.w, "resumed run diverged from the uninterrupted one");
    assert_same_trajectory(&a.history, &o.history, "late-set resume");
    assert_eq!(a.history.staleness, o.history.staleness, "staleness logs diverged");
    assert!(
        a.history.staleness.iter().map(|r| r.folds).sum::<usize>() > 0,
        "the parked slice never folded — the test proved nothing"
    );
}

// ---- SODDA_STALENESS plumbing ----------------------------------------------

#[test]
fn env_policy_is_staged_and_explicit_pins_win() {
    let auto = || base(80, 16, 2, 2, 3).build().unwrap();
    with_staleness_env(Some("0.75:2:4"), || {
        let t = Trainer::new(auto()).unwrap();
        assert_eq!(t.staleness(), Some(quorum()), "staging must pick up the env policy");

        // an explicit pin beats the environment
        let pinned = base(80, 16, 2, 2, 3).staleness(StalenessPolicy::default()).build().unwrap();
        let t = Trainer::new(pinned).unwrap();
        assert_eq!(t.staleness(), Some(StalenessPolicy::default()));
    });
    with_staleness_env(None, || {
        assert!(Trainer::new(auto()).unwrap().staleness().is_none());
    });
}

#[test]
fn malformed_env_policy_is_a_staging_error() {
    let auto = || base(80, 16, 2, 2, 3).build().unwrap();
    for bad in ["nonsense", "0.75:2:4:9", "0.5:0", "2.0"] {
        with_staleness_env(Some(bad), || {
            let err = match Trainer::new(auto()) {
                Ok(_) => panic!("malformed env {bad:?} must fail staging"),
                Err(e) => e,
            };
            let chain = format!("{err:#}");
            assert!(
                chain.contains(StalenessPolicy::ENV),
                "unhelpful error for {bad:?}: {chain}"
            );
        });
    }
}
