//! Property tests for the batched kernel layer (`engine::kernels`).
//!
//! The batched dense/CSR kernels must match the per-row scalar path
//! **bit-for-bit** — they share one per-row accumulation order with the
//! `Store` scalar ops, so batching/fusion/blocking may change
//! throughput but never bits. Cases sweep random shapes, random column
//! sub-ranges (including empty), and row sets from empty through full,
//! for both storage formats; `assert_eq!` on the raw f32/f64 values is
//! the whole point (no tolerances).

use sodda::data::{CsrMatrix, DenseMatrix, Store};
use sodda::engine::kernels;
use sodda::loss::Loss;
use sodda::util::rng::Rng;
use sodda::util::testing::forall;

fn dense(rng: &mut Rng, n: usize, m: usize) -> Store {
    let mut d = DenseMatrix::zeros(n, m);
    for v in d.data.iter_mut() {
        *v = rng.f32_range(-1.0, 1.0);
    }
    Store::Dense(d)
}

fn sparse(rng: &mut Rng, n: usize, m: usize) -> Store {
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let nnz = rng.below(m + 1); // rows may be empty
        let cols = rng.sample_without_replacement(m, nnz);
        entries.push(cols.into_iter().map(|c| (c, rng.f32_range(-1.0, 1.0))).collect());
    }
    Store::Sparse(CsrMatrix::from_row_entries(n, m, entries))
}

struct Case {
    x: Store,
    y: Vec<f32>,
    lo: usize,
    hi: usize,
    w: Vec<f32>,
    rows: Vec<u32>,
    u: Vec<f32>,
}

fn case(rng: &mut Rng, sparse_fmt: bool) -> Case {
    let n = 1 + rng.below(40);
    let m = 1 + rng.below(64);
    let x = if sparse_fmt { sparse(rng, n, m) } else { dense(rng, n, m) };
    let y: Vec<f32> = (0..n).map(|_| if rng.bool_with(0.5) { 1.0 } else { -1.0 }).collect();
    let lo = rng.below(m + 1);
    let hi = lo + rng.below(m - lo + 1); // may be empty (hi == lo)
    let w: Vec<f32> = (0..hi - lo).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let k = rng.below(n + 1); // 0 => empty row set
    let rows = rng.sample_without_replacement(n, k);
    // exact zeros mixed in, like hinge derivatives (exercises the
    // zero-skip in the blocked axpy)
    let u: Vec<f32> = (0..rows.len())
        .map(|i| if i % 3 == 0 { 0.0 } else { rng.f32_range(-1.0, 1.0) })
        .collect();
    Case { x, y, lo, hi, w, rows, u }
}

fn scalar_partial_z(c: &Case) -> Vec<f32> {
    c.rows.iter().map(|&r| c.x.row_dot_range(r as usize, c.lo, c.hi, &c.w)).collect()
}

#[test]
fn batched_partial_z_is_bit_for_bit_scalar() {
    for sparse_fmt in [false, true] {
        forall(150, 0xA1 + sparse_fmt as u64, |rng| {
            let c = case(rng, sparse_fmt);
            let z = kernels::partial_z(&c.x, c.lo..c.hi, &c.w, &c.rows);
            assert_eq!(z, scalar_partial_z(&c), "sparse={sparse_fmt}");
        });
    }
}

#[test]
fn batched_grad_slice_is_bit_for_bit_scalar() {
    for sparse_fmt in [false, true] {
        forall(150, 0xB1 + sparse_fmt as u64, |rng| {
            let c = case(rng, sparse_fmt);
            let g = kernels::grad_slice(&c.x, c.lo..c.hi, &c.rows, &c.u);
            let mut want = vec![0.0f32; c.hi - c.lo];
            for (&r, &uk) in c.rows.iter().zip(&c.u) {
                c.x.add_row_scaled_range(r as usize, c.lo, c.hi, uk, &mut want);
            }
            assert_eq!(g, want, "sparse={sparse_fmt}");
        });
    }
}

#[test]
fn fused_partial_u_is_bit_for_bit_composition() {
    for sparse_fmt in [false, true] {
        forall(100, 0xC1 + sparse_fmt as u64, |rng| {
            let c = case(rng, sparse_fmt);
            let z = scalar_partial_z(&c);
            for loss in Loss::ALL {
                let got = kernels::partial_u(loss, &c.x, c.lo..c.hi, &c.w, &c.rows, &c.y);
                let want: Vec<f32> = z
                    .iter()
                    .zip(&c.rows)
                    .map(|(&zk, &r)| loss.dloss(zk, c.y[r as usize]))
                    .collect();
                assert_eq!(got, want, "sparse={sparse_fmt} {loss}");
            }
        });
    }
}

#[test]
fn fused_block_loss_is_bit_for_bit_composition() {
    for sparse_fmt in [false, true] {
        forall(100, 0xD1 + sparse_fmt as u64, |rng| {
            let c = case(rng, sparse_fmt);
            let z = scalar_partial_z(&c);
            for loss in Loss::ALL {
                let got = kernels::block_loss(loss, &c.x, c.lo..c.hi, &c.w, &c.rows, &c.y);
                let want: f64 = z
                    .iter()
                    .zip(&c.rows)
                    .map(|(&zk, &r)| loss.value(zk, c.y[r as usize]) as f64)
                    .sum();
                assert_eq!(got, want, "sparse={sparse_fmt} {loss}");
            }
        });
    }
}

/// The pre-fusion inner loop: two independent row-dots per step,
/// straight over the `Store` scalar ops.
#[allow(clippy::too_many_arguments)]
fn scalar_svrg(
    loss: Loss,
    x: &Store,
    y: &[f32],
    lo: usize,
    hi: usize,
    w0: &[f32],
    wt: &[f32],
    mu: &[f32],
    idx: &[u32],
    gamma: f32,
    avg: bool,
) -> Vec<f32> {
    let mut w = w0.to_vec();
    let mut acc = vec![0.0f32; w.len()];
    for &j in idx {
        let j = j as usize;
        let z_cur = x.row_dot_range(j, lo, hi, &w);
        let z_ref = x.row_dot_range(j, lo, hi, wt);
        let du = loss.dloss(z_cur, y[j]) - loss.dloss(z_ref, y[j]);
        if du != 0.0 {
            x.add_row_scaled_range(j, lo, hi, -gamma * du, &mut w);
        }
        for (wk, &mk) in w.iter_mut().zip(mu) {
            *wk -= gamma * mk;
        }
        for (a, &wk) in acc.iter_mut().zip(&w) {
            *a += wk;
        }
    }
    if avg {
        let inv = 1.0 / idx.len() as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        acc
    } else {
        w
    }
}

#[test]
fn fused_svrg_is_bit_for_bit_two_pass() {
    for sparse_fmt in [false, true] {
        forall(80, 0xE1 + sparse_fmt as u64, |rng| {
            let c = case(rng, sparse_fmt);
            let mt = c.hi - c.lo;
            let n = c.x.rows();
            let w0: Vec<f32> = (0..mt).map(|_| rng.f32_range(-0.5, 0.5)).collect();
            let wt: Vec<f32> = (0..mt).map(|_| rng.f32_range(-0.5, 0.5)).collect();
            let mu: Vec<f32> = (0..mt).map(|_| rng.f32_range(-0.1, 0.1)).collect();
            let idx = rng.sample_with_replacement(n, 1 + rng.below(24));
            let gamma = 0.07f32;
            for loss in Loss::ALL {
                let got =
                    kernels::svrg_inner(loss, &c.x, &c.y, c.lo..c.hi, &w0, &wt, &mu, &idx, gamma);
                let want =
                    scalar_svrg(loss, &c.x, &c.y, c.lo, c.hi, &w0, &wt, &mu, &idx, gamma, false);
                assert_eq!(got, want, "sparse={sparse_fmt} {loss} last-iterate");
                let got = kernels::svrg_inner_avg(
                    loss, &c.x, &c.y, c.lo..c.hi, &w0, &wt, &mu, &idx, gamma,
                );
                let want =
                    scalar_svrg(loss, &c.x, &c.y, c.lo, c.hi, &w0, &wt, &mu, &idx, gamma, true);
                assert_eq!(got, want, "sparse={sparse_fmt} {loss} averaged");
            }
        });
    }
}

/// End-to-end: a Q = 1 grid routes the µ estimate and objective through
/// the fused on-worker `partial_u`/`block_loss` cluster commands; the
/// run must be deterministic and actually train.
#[test]
fn q1_training_drives_fused_worker_path() {
    use sodda::{ExperimentConfig, Trainer};
    let cfg = ExperimentConfig::builder()
        .name("q1-fused")
        .dense(300, 30)
        .grid(3, 1)
        .outer_iters(10)
        .seed(9)
        .build()
        .unwrap();
    let a = Trainer::new(cfg.clone()).unwrap().run().unwrap();
    let b = Trainer::new(cfg).unwrap().run().unwrap();
    let losses = a.history.losses();
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(
        losses.last().unwrap() < &(0.9 * losses[0]),
        "no progress on q=1 grid: {losses:?}"
    );
    assert_eq!(losses, b.history.losses(), "fused q=1 path must be deterministic");
    assert_eq!(a.w, b.w);
}
