//! Executor equivalence suite: the in-process oracle and the threaded
//! runtime must be interchangeable down to the last bit.
//!
//! The transport layer's determinism contract (see
//! `cluster/transport/`) says the execution substrate is invisible to
//! the numbers: same shared worker body, id-ordered reduces, disjoint
//! SVRG write ranges. These tests pin that contract at the session
//! level — full seeded `History` + final-iterate equality across
//! dense/CSR storage, even/ragged grids, `Q > 1`, sampled widths and
//! every algorithm — plus the executor-selection plumbing (config pin
//! beats the `SODDA_EXECUTOR` env knob beats the in-process default).
//!
//! Selection tests mutate the process environment, so they serialize on
//! the crate-wide `util::env` lock (via `ScopedEnv`) and restore the
//! prior value (the CI threaded lane sets `SODDA_EXECUTOR` globally);
//! every other test pins its executor through the config and never
//! reads the environment.

use sodda::config::{AlgorithmKind, ExecutorKind};
use sodda::util::testing::forall;
use sodda::{ExperimentConfig, ExperimentConfigBuilder, Trainer};

fn base(n: usize, m: usize, p: usize, q: usize, iters: usize) -> ExperimentConfigBuilder {
    ExperimentConfig::builder()
        .name("executor-equivalence")
        .dense(n, m)
        .grid(p, q)
        .inner_steps(8)
        .outer_iters(iters)
        .eval_every(1)
        .seed(7)
}

/// Run the identical config on both executors and demand bit equality
/// of the final iterate, the full loss history, and the simulated-wire
/// accounting.
fn assert_executors_agree(b: ExperimentConfigBuilder, label: &str) {
    let mut oracle =
        Trainer::new(b.clone().executor(ExecutorKind::InProcess).build().unwrap()).unwrap();
    let a = oracle.run().unwrap();
    let mut threaded =
        Trainer::new(b.executor(ExecutorKind::Threaded).build().unwrap()).unwrap();
    let t = threaded.run().unwrap();
    assert_eq!(a.w, t.w, "{label}: final iterate diverged");
    assert_eq!(a.history.losses(), t.history.losses(), "{label}: loss history diverged");
    assert_eq!(a.comm_bytes, t.comm_bytes, "{label}: wire accounting diverged");
    assert_eq!(a.comm_msgs, t.comm_msgs, "{label}: message accounting diverged");
}

#[test]
fn threaded_reproduces_oracle_across_random_sessions() {
    // dense/CSR × even/ragged × Q ∈ {1,2,3} × all algorithms × sampled
    // and full widths, three outer iterations each
    forall(8, 20260807, |rng| {
        let p = 1 + rng.below(3);
        let q = 1 + rng.below(3);
        let n = p * (4 + rng.below(40)) + rng.below(p);
        let m = (p * q) * (2 + rng.below(6)) + rng.below(3);
        let algo = match rng.below(3) {
            0 => AlgorithmKind::Sodda,
            1 => AlgorithmKind::Radisa,
            _ => AlgorithmKind::RadisaAvg,
        };
        let mut b = base(n, m, p, q, 3).algorithm(algo).seed(rng.below(1000) as u64);
        if rng.bool_with(0.5) {
            b = b.sparse(n, m, 4);
        }
        if algo == AlgorithmKind::Sodda && rng.bool_with(0.5) {
            // aggressive sampling: compact-payload phases on both sides
            b = b.fractions_bcd(0.4, 0.3, 0.7);
        }
        assert_executors_agree(b, &format!("{algo:?} {n}x{m} on {p}x{q}"));
    });
}

#[test]
fn threaded_reproduces_oracle_on_ragged_sampled_grid() {
    // the fixed worst-case composition: ragged rows and columns, Q > 1
    // (leader-side z reduce), low sampled fractions (empty per-block
    // intersections happen), CSR storage
    let b = base(97, 23, 3, 2, 4).sparse(97, 23, 5).fractions_bcd(0.35, 0.25, 0.6);
    assert_executors_agree(b, "sodda sampled sparse 97x23 on 3x2");
}

#[test]
fn threaded_runs_are_seed_reproducible() {
    // same seed, two fresh threaded sessions: completion order may vary
    // between runs, results may not
    let cfg = || base(85, 18, 2, 3, 4).executor(ExecutorKind::Threaded).build().unwrap();
    let a = Trainer::new(cfg()).unwrap().run().unwrap();
    let b = Trainer::new(cfg()).unwrap().run().unwrap();
    assert_eq!(a.w, b.w);
    assert_eq!(a.history.losses(), b.history.losses());
}

#[test]
fn threaded_pooling_is_bit_identical_to_fresh_buffers() {
    // PR 4's contract under the threaded transport: recycling reply
    // buffers through channels changes no numbers
    let cfg = base(120, 24, 2, 2, 4).executor(ExecutorKind::Threaded).build().unwrap();
    let mut warm = Trainer::new(cfg.clone()).unwrap();
    let a = warm.run().unwrap();
    let mut cold = Trainer::new(cfg).unwrap();
    while !cold.is_done() {
        cold.drop_scratch();
        cold.step().unwrap();
    }
    let o = cold.outcome();
    assert_eq!(a.w, o.w);
    assert_eq!(a.history.losses(), o.history.losses());
}

#[test]
fn reconfigure_rejects_switching_executors() {
    let pinned = |k: ExecutorKind| base(80, 12, 2, 2, 2).executor(k).build().unwrap();
    let mut t = Trainer::new(pinned(ExecutorKind::InProcess)).unwrap();
    assert_eq!(t.executor(), ExecutorKind::InProcess);
    let err = t.reconfigure(pinned(ExecutorKind::Threaded)).unwrap_err();
    assert!(err.to_string().contains("executor"), "unhelpful error: {err}");
    // same kind, new seed: fine
    let variant = base(80, 12, 2, 2, 2).executor(ExecutorKind::InProcess).seed(99).build().unwrap();
    assert!(t.reconfigure(variant).is_ok());
}

// ---- selection plumbing (mutates the process env; serialized) -------------

/// Run `f` with `SODDA_EXECUTOR` set to `value` (or unset for `None`).
/// `ScopedEnv` holds the process-wide env lock for the scope and
/// restores whatever was there before (even on panic) — the CI
/// threaded lane exports the knob process-wide and must still see it
/// afterwards.
fn with_env(value: Option<&str>, f: impl FnOnce()) {
    let _env = sodda::util::env::ScopedEnv::new().with(ExecutorKind::ENV, value);
    f();
}

#[test]
fn env_knob_selects_the_executor() {
    let auto = || base(80, 12, 2, 2, 2).build().unwrap();
    with_env(Some("threaded"), || {
        assert_eq!(Trainer::new(auto()).unwrap().executor(), ExecutorKind::Threaded);
    });
    with_env(Some("in-process"), || {
        assert_eq!(Trainer::new(auto()).unwrap().executor(), ExecutorKind::InProcess);
    });
    with_env(None, || {
        assert_eq!(
            Trainer::new(auto()).unwrap().executor(),
            ExecutorKind::InProcess,
            "unset env must default to the oracle"
        );
    });
}

#[test]
fn config_pin_beats_the_env_knob() {
    with_env(Some("threaded"), || {
        let cfg = base(80, 12, 2, 2, 2).executor(ExecutorKind::InProcess).build().unwrap();
        assert_eq!(Trainer::new(cfg).unwrap().executor(), ExecutorKind::InProcess);
    });
}

#[test]
fn garbage_env_value_is_an_error_not_a_fallback() {
    with_env(Some("gpu-cluster"), || {
        let err = Trainer::new(base(80, 12, 2, 2, 2).build().unwrap()).unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("SODDA_EXECUTOR"), "unhelpful error: {chain}");
    });
}
