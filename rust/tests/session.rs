//! Integration tests for the session API: builder validation at the
//! public surface, streaming observers (early stop, deadlines), session
//! reuse across sweep runs, warm starts, and the step-driven loop.

use std::ops::ControlFlow;

use sodda::config::{AlgorithmKind, Schedule};
use sodda::train::observers;
use sodda::{ExperimentConfig, ExperimentConfigBuilder, Trainer};

fn base() -> ExperimentConfigBuilder {
    ExperimentConfig::builder()
        .name("session-test")
        .dense(300, 60)
        .grid(3, 2)
        .inner_steps(8)
        .outer_iters(6)
        .seed(7)
}

// ---------------------------------------------------------------------------
// builder validation
// ---------------------------------------------------------------------------

#[test]
fn builder_accepts_ragged_grids_unless_strict() {
    // N = 300 not divisible by P = 7, M = 60 not divisible by Q·P = 9:
    // both are fine by default — the partitioner goes ragged
    assert!(base().grid(7, 2).build().is_ok());
    assert!(base().grid(3, 3).build().is_ok());
    assert!(base().grid(3, 2).build().is_ok());
    // the historical strict mode lives behind require_even_grid()
    assert!(base().grid(7, 2).require_even_grid().build().is_err());
    assert!(base().grid(3, 3).require_even_grid().build().is_err());
    assert!(base().grid(3, 2).require_even_grid().build().is_ok());
}

#[test]
fn builder_rejects_out_of_range_fractions() {
    assert!(base().fractions_bcd(0.0, 0.0, 0.5).build().is_err(), "b = 0");
    assert!(base().fractions_bcd(1.2, 0.8, 0.5).build().is_err(), "b > 1");
    assert!(base().fractions_bcd(0.5, 0.8, 0.5).build().is_err(), "c > b");
    assert!(base().fractions_bcd(0.9, 0.8, -0.1).build().is_err(), "d < 0");
    assert!(base().fractions_bcd(0.9, 0.8, 0.9).build().is_ok());
}

#[test]
fn builder_rejects_zero_iterations_and_bad_schedules() {
    assert!(base().outer_iters(0).build().is_err());
    assert!(base().inner_steps(0).build().is_err());
    assert!(base().schedule(Schedule::Constant { gamma: 0.0 }).build().is_err());
    assert!(base().schedule(Schedule::ScaledSqrt { gamma0: f64::NAN }).build().is_err());
}

#[test]
fn builder_requires_data() {
    assert!(ExperimentConfig::builder().build().is_err());
}

// ---------------------------------------------------------------------------
// observers
// ---------------------------------------------------------------------------

#[test]
fn observer_early_stop_halts_with_truncated_history() {
    let cfg = base().outer_iters(20).build().unwrap();
    let mut trainer = Trainer::new(cfg).unwrap();
    let out = trainer.run_with_observer(observers::at_iteration(5)).unwrap();
    // stopped exactly at the requested iteration: records 0..=5
    assert_eq!(out.history.records.last().unwrap().iter, 5);
    assert_eq!(out.history.records.len(), 6);
    assert_eq!(trainer.iteration(), 5);
    assert!(!trainer.is_done(), "early stop leaves the run resumable");
}

#[test]
fn observer_streams_every_record_in_order() {
    let cfg = base().build().unwrap();
    let mut trainer = Trainer::new(cfg).unwrap();
    let mut iters = Vec::new();
    let out = trainer
        .run_with_observer(|r| {
            iters.push(r.iter);
            ControlFlow::Continue(())
        })
        .unwrap();
    assert_eq!(iters, (0..=6).collect::<Vec<_>>());
    assert_eq!(out.history.records.len(), 7);
}

#[test]
fn loss_target_observer_stops_before_the_horizon() {
    let cfg = base().outer_iters(40).build().unwrap();
    let mut trainer = Trainer::new(cfg).unwrap();
    // hinge loss at ω^0 = 0 is exactly 1; target a 5% reduction
    let mut target = observers::loss_below(0.95);
    let out = trainer.run_with_observer(&mut target).unwrap();
    assert_eq!(out.history.records[0].loss, 1.0, "F(0) for hinge is 1");
    assert!(out.history.final_loss().unwrap() <= 0.95);
    assert!(trainer.iteration() < 40, "should reach an easy target early");
}

// ---------------------------------------------------------------------------
// session reuse (the fig2/table2 sweep pattern)
// ---------------------------------------------------------------------------

#[test]
fn two_sweep_runs_on_one_session_match_two_fresh_sessions() {
    let cfg_a = base().name("sweep-a").fractions_bcd(0.85, 0.80, 0.85).build().unwrap();
    let cfg_b = base().name("sweep-b").algorithm(AlgorithmKind::RadisaAvg).build().unwrap();

    // one staged session, two runs
    let mut session = Trainer::new(cfg_a.clone()).unwrap();
    let shared_a = session.run().unwrap();
    session.reconfigure(cfg_b.clone()).unwrap();
    let shared_b = session.run().unwrap();

    // a fresh session per run
    let mut fresh = Trainer::new(cfg_a).unwrap();
    let fresh_a = fresh.run().unwrap();
    let mut fresh = Trainer::new(cfg_b).unwrap();
    let fresh_b = fresh.run().unwrap();

    assert_eq!(shared_a.w, fresh_a.w, "reused session must not perturb run A");
    assert_eq!(shared_a.history.losses(), fresh_a.history.losses());
    assert_eq!(shared_b.w, fresh_b.w, "reused session must not perturb run B");
    assert_eq!(shared_b.history.losses(), fresh_b.history.losses());
}

#[test]
fn reseeded_runs_on_one_session_differ_and_reproduce() {
    let cfg = base().build().unwrap();
    let mut session = Trainer::new(cfg.clone()).unwrap();
    let a = session.run().unwrap();
    session.reconfigure(cfg.to_builder().seed(8).build().unwrap()).unwrap();
    let b = session.run().unwrap();
    assert_ne!(a.w, b.w, "different training seed must change the trajectory");
    session.reconfigure(cfg).unwrap();
    let a2 = session.run().unwrap();
    assert_eq!(a.w, a2.w, "same config must reproduce bit-for-bit");
}

#[test]
fn reconfigure_rejects_grid_loss_and_dim_changes() {
    let mut session = Trainer::new(base().build().unwrap()).unwrap();
    assert!(session.reconfigure(base().grid(1, 1).build().unwrap()).is_err());
    assert!(session
        .reconfigure(base().loss(sodda::loss::Loss::Squared).build().unwrap())
        .is_err());
    assert!(session.reconfigure(base().dense(600, 60).build().unwrap()).is_err());
}

// ---------------------------------------------------------------------------
// warm starts and step-driven runs
// ---------------------------------------------------------------------------

#[test]
fn warm_start_chains_runs_from_the_prior_iterate() {
    let mut session = Trainer::new(base().build().unwrap()).unwrap();
    let first = session.run().unwrap();
    session.warm_start(&first.w).unwrap();
    let second = session.run().unwrap();
    // iteration 0 of the chained run evaluated F at the warm-start point
    assert_eq!(second.history.records[0].loss, first.history.final_loss().unwrap());
    assert!(
        second.history.final_loss().unwrap() < first.history.losses()[0],
        "chained run must stay far below the cold start"
    );
    // wrong length is rejected
    assert!(session.warm_start(&[0.0; 3]).is_err());
}

#[test]
fn step_driven_loop_matches_run() {
    let cfg = base().build().unwrap();
    let mut a = Trainer::new(cfg.clone()).unwrap();
    let ra = a.run().unwrap();

    let mut b = Trainer::new(cfg).unwrap();
    let mut recorded = 1; // iteration 0
    while !b.is_done() {
        if b.step().unwrap().is_some() {
            recorded += 1;
        }
    }
    assert!(b.step().is_err(), "stepping past the horizon is an error");
    let rb = b.outcome();
    assert_eq!(ra.w, rb.w);
    assert_eq!(ra.history.losses(), rb.history.losses());
    assert_eq!(recorded, rb.history.records.len());
}

#[test]
fn legacy_shim_matches_session_run() {
    let cfg = base().build().unwrap();
    let shim = sodda::coordinator::train(&cfg).unwrap();
    let mut session = Trainer::new(cfg).unwrap();
    let direct = session.run().unwrap();
    assert_eq!(shim.w, direct.w);
    assert_eq!(shim.history.losses(), direct.history.losses());
}
