//! Unreliable-cluster suite: fault injection with leader-side recovery,
//! permanent loss with elastic re-sharding, and checkpoint/resume.
//!
//! Two contracts under test. *Bit-transparency*: a run that loses (and
//! recovers) workers mid-phase, or that is checkpointed to JSON and
//! resumed in a fresh session, must reproduce the uninterrupted
//! fault-free trajectory exactly — same iterate, same losses, same
//! simulated-cost and wire accounting (`wall_s` excepted: wall clocks
//! restart with the process). *Degradation equivalence*: a run that
//! loses a worker **permanently** must, from the loss on, be
//! bit-identical to a fresh run staged on the shrunk grid and
//! warm-started from the last completed iteration — offset only by the
//! honestly-charged shuffle cost of the re-shard.
//!
//! The permanent-loss tests carry `perm` in their names: the CI
//! escalation lane exports a `!perm` plan and filters to them (an
//! escalating plan breaks the transparency contract the other tests
//! pin, by design).
//!
//! Staging a `Trainer` reads `SODDA_FAULT_PLAN`, so every test in this
//! binary serializes on the crate-wide `util::env` lock: the
//! env-mutating tests swap the knob under it (`ScopedEnv`), and the
//! rest hold it so they never stage mid-swap. (The `rust-faults` CI
//! lane exports a plan process-wide; tests that need a specific
//! schedule set it through `set_fault_plan`, which overrides the
//! environment either way.)

use std::sync::MutexGuard;

use sodda::config::ExecutorKind;
use sodda::metrics::History;
use sodda::train::FAULT_PLAN_ENV;
use sodda::util::json::Value;
use sodda::util::testing::forall;
use sodda::{ExperimentConfig, ExperimentConfigBuilder, FaultPlan, RunState, Trainer};

fn locked() -> MutexGuard<'static, ()> {
    sodda::util::env::lock()
}

/// Run `f` with `SODDA_FAULT_PLAN` set to `value` (unset for `None`).
/// `ScopedEnv` holds the process-wide env lock for the scope and
/// restores the prior value (even on panic) — the CI fault lane
/// exports the knob process-wide and must still see it afterwards.
fn with_plan_env(value: Option<&str>, f: impl FnOnce()) {
    let _env = sodda::util::env::ScopedEnv::new().with(FAULT_PLAN_ENV, value);
    f();
}

fn base(n: usize, m: usize, p: usize, q: usize, iters: usize) -> ExperimentConfigBuilder {
    ExperimentConfig::builder()
        .name("faults-suite")
        .dense(n, m)
        .grid(p, q)
        .inner_steps(8)
        .outer_iters(iters)
        .eval_every(1)
        .seed(11)
}

/// Everything trajectory equality means, minus `wall_s`.
fn assert_same_trajectory(a: &History, b: &History, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count diverged");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.iter, y.iter, "{label}: record cadence diverged");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{label}: loss at iter {}", x.iter);
        assert_eq!(x.sim_s.to_bits(), y.sim_s.to_bits(), "{label}: sim_s at iter {}", x.iter);
        assert_eq!(x.comm_bytes, y.comm_bytes, "{label}: comm_bytes at iter {}", x.iter);
        assert_eq!(
            x.grad_coord_evals, y.grad_coord_evals,
            "{label}: grad_coord_evals at iter {}",
            x.iter
        );
    }
}

// ---- fault recovery --------------------------------------------------------

/// ISSUE 7 acceptance: a seeded run killing k ∈ {1, 2} workers at
/// seeded (iteration, phase) points reproduces the fault-free `History`
/// bit-for-bit, on both executors.
#[test]
fn seeded_kills_reproduce_the_fault_free_run_bit_for_bit() {
    let _g = locked();
    for kind in [ExecutorKind::InProcess, ExecutorKind::Threaded] {
        for k in [1usize, 2] {
            let cfg = || base(90, 18, 2, 2, 5).executor(kind).build().unwrap();
            let mut clean = Trainer::new(cfg()).unwrap();
            clean.set_fault_plan(None);
            let a = clean.run().unwrap();

            let plan = FaultPlan::seeded(0xDEAD + k as u64, k, 4, 5);
            let mut faulted = Trainer::new(cfg()).unwrap();
            faulted.set_fault_plan(Some(plan.clone()));
            let b = faulted.run().unwrap();

            let label = format!("{kind} k={k} plan=[{plan}]");
            assert_eq!(a.w, b.w, "{label}: final iterate diverged");
            assert_same_trajectory(&a.history, &b.history, &label);
            assert_eq!(a.comm_bytes, b.comm_bytes, "{label}: wire accounting diverged");
            assert_eq!(a.comm_msgs, b.comm_msgs, "{label}: message accounting diverged");
            assert!(a.history.faults.is_empty(), "{label}: clean run logged faults");
            assert!(
                !faulted.history().faults.is_empty(),
                "{label}: the plan never fired — the test proved nothing"
            );
        }
    }
}

/// Property: the two executors agree bit-for-bit *under the same seeded
/// fault plan* — recovery must be deterministic on both substrates, not
/// merely transparent on each.
#[test]
fn executors_agree_under_the_same_fault_plan() {
    let _g = locked();
    forall(6, 20260808, |rng| {
        let p = 1 + rng.below(3);
        let q = 1 + rng.below(3);
        let n = p * (5 + rng.below(30)) + rng.below(p);
        let m = (p * q) * (2 + rng.below(5)) + rng.below(3);
        let iters = 3;
        let plan = FaultPlan::seeded(rng.below(1_000_000) as u64, 1 + rng.below(3), p * q, iters);
        let mut b = base(n, m, p, q, iters).seed(rng.below(1000) as u64);
        if rng.bool_with(0.5) {
            b = b.sparse(n, m, 4);
        }
        if rng.bool_with(0.5) {
            b = b.fractions_bcd(0.4, 0.3, 0.7);
        }
        let run = |kind: ExecutorKind| {
            let mut t = Trainer::new(b.clone().executor(kind).build().unwrap()).unwrap();
            t.set_fault_plan(Some(plan.clone()));
            (t.run().unwrap(), t.history().faults.clone())
        };
        let (a, fa) = run(ExecutorKind::InProcess);
        let (t, ft) = run(ExecutorKind::Threaded);
        let label = format!("{n}x{m} on {p}x{q}, plan=[{plan}]");
        assert_eq!(a.w, t.w, "{label}: final iterate diverged");
        assert_same_trajectory(&a.history, &t.history, &label);
        assert_eq!(a.comm_bytes, t.comm_bytes, "{label}: wire accounting diverged");
        assert_eq!(fa, ft, "{label}: fault logs diverged");
    });
}

#[test]
fn fault_log_records_what_the_plan_scheduled() {
    let _g = locked();
    let plan: FaultPlan = "3@2:mu,0@2:grad,1@4:inner".parse().unwrap();
    let mut t = Trainer::new(base(80, 16, 2, 2, 5).build().unwrap()).unwrap();
    t.set_fault_plan(Some(plan));
    t.run().unwrap();
    let seen: Vec<String> =
        t.history().faults.iter().map(|f| format!("{}@{}:{}", f.worker, f.iter, f.phase)).collect();
    assert_eq!(seen, vec!["3@2:mu", "0@2:grad", "1@4:inner"]);
    // and the log survives the history's JSON round trip
    let v = Value::parse(&t.history().to_json().to_string_pretty()).unwrap();
    assert_eq!(History::from_json(&v).unwrap().faults, t.history().faults);
}

// ---- permanent loss / elastic re-sharding ----------------------------------

/// ISSUE 9 acceptance: a run that permanently loses a worker at
/// iteration t escalates, re-shards, and continues **as the shrunk-grid
/// run** — bit-identical from t on to a fresh session staged at the
/// shrunk grid and warm-started from the (t-1)-th checkpoint. The only
/// difference is the honestly-accounted shuffle: `sim_s`/`comm_bytes`
/// offset by exactly the [`ReshardRecord`]'s charge. Both executors,
/// dense + CSR, even + ragged; the executors must also agree with each
/// other on every observable, fault and re-shard logs included.
#[test]
fn permanent_loss_continues_as_the_shrunk_grid_run() {
    let _g = locked();
    let t_kill = 3usize;
    let shapes: [(ExperimentConfigBuilder, &str); 4] = [
        (base(120, 24, 2, 2, 6), "dense even"),
        (base(97, 23, 3, 2, 6), "dense ragged"),
        (base(120, 24, 2, 2, 6).sparse(120, 24, 4), "csr even"),
        (base(85, 19, 2, 3, 6).sparse(85, 19, 5), "csr ragged"),
    ];
    for (b, shape) in shapes {
        let mut per_kind = Vec::new();
        for kind in [ExecutorKind::InProcess, ExecutorKind::Threaded] {
            let label = format!("{shape} on {kind}");
            let mut lossy = Trainer::new(b.clone().executor(kind).build().unwrap()).unwrap();
            lossy.set_fault_plan(Some("1@3:grad!perm".parse().unwrap()));
            let a = lossy.run().unwrap();
            assert_eq!(a.history.reshards.len(), 1, "{label}: expected exactly one re-shard");
            let r = a.history.reshards[0];
            assert_eq!((r.iter, r.worker), (t_kill, 1), "{label}: wrong re-shard provenance");
            assert!(r.bytes > 0 && r.sim_s > 0.0, "{label}: shuffle must cost bytes and time");

            // the reference: the same run, fault-free, checkpointed at
            // t-1 and warm-started into a session staged directly on
            // the shrunk grid
            let mut pre = Trainer::new(b.clone().executor(kind).build().unwrap()).unwrap();
            pre.set_fault_plan(None);
            for _ in 0..t_kill - 1 {
                pre.step().unwrap();
            }
            let shrunk = b.clone().grid(r.to_p, r.to_q).executor(kind).build().unwrap();
            let mut reference = Trainer::resume(shrunk, pre.checkpoint()).unwrap();
            reference.set_fault_plan(None);
            let o = reference.run().unwrap();

            assert_eq!(a.w, o.w, "{label}: final iterate diverged from the shrunk-grid run");
            assert_eq!(a.history.records.len(), o.history.records.len(), "{label}");
            for (x, y) in a.history.records.iter().zip(&o.history.records) {
                assert_eq!(x.iter, y.iter, "{label}: cadence diverged");
                assert_eq!(
                    x.loss.to_bits(),
                    y.loss.to_bits(),
                    "{label}: loss at iter {}",
                    x.iter
                );
                assert_eq!(
                    x.grad_coord_evals, y.grad_coord_evals,
                    "{label}: grad_coord_evals at iter {}",
                    x.iter
                );
                if x.iter < t_kill {
                    // before the loss: the original grid's own numbers
                    assert_eq!(x.sim_s.to_bits(), y.sim_s.to_bits(), "{label}: iter {}", x.iter);
                    assert_eq!(x.comm_bytes, y.comm_bytes, "{label}: iter {}", x.iter);
                } else {
                    // after: offset by exactly the shuffle charge
                    assert_eq!(
                        x.comm_bytes,
                        y.comm_bytes + r.bytes,
                        "{label}: comm_bytes at iter {} must carry the re-shard bytes",
                        x.iter
                    );
                    let want = y.sim_s + r.sim_s;
                    assert!(
                        (x.sim_s - want).abs() <= 1e-9 * want.abs().max(1.0),
                        "{label}: sim_s at iter {} is {} but shrunk-run + shuffle is {}",
                        x.iter,
                        x.sim_s,
                        want
                    );
                }
            }
            per_kind.push((a, lossy.history().faults.clone()));
        }
        // deterministic observable escalation: both executors produce
        // identical trajectories *and* identical fault/re-shard logs
        let (a, fa) = &per_kind[0];
        let (t, ft) = &per_kind[1];
        assert_eq!(a.w, t.w, "{shape}: executors diverged under permanent loss");
        assert_same_trajectory(&a.history, &t.history, &format!("{shape}: cross-executor"));
        assert_eq!(fa, ft, "{shape}: fault logs diverged across executors");
        assert_eq!(a.history.reshards, t.history.reshards, "{shape}: re-shard logs diverged");
    }
}

/// An env-exported `!perm` plan (the CI escalation lane's knob) stages,
/// escalates, re-shards, and leaves the run on the shrunk grid.
#[test]
fn env_perm_plan_escalates_and_reshards() {
    with_plan_env(Some("1@2:grad!perm"), || {
        let mut t = Trainer::new(base(80, 16, 2, 2, 4).build().unwrap()).unwrap();
        t.run().unwrap();
        assert_eq!(t.history().reshards.len(), 1);
        assert!(t.history().faults.iter().any(|f| f.perm), "the kill must be logged as permanent");
        assert_eq!((t.config().p, t.config().q), (1, 2), "the grid must have shrunk");
        assert!(t.is_done(), "the degraded run must still complete its horizon");
    });
}

// ---- checkpoint / resume ---------------------------------------------------

/// Checkpoint at every possible boundary t, resume in a fresh session,
/// and demand the remaining trajectory matches the uninterrupted run —
/// across dense/CSR × even/ragged shapes.
#[test]
fn checkpoint_resume_reproduces_the_trajectory() {
    let _g = locked();
    let shapes: [(ExperimentConfigBuilder, &str); 4] = [
        (base(120, 24, 2, 2, 5), "dense even"),
        (base(97, 23, 3, 2, 5), "dense ragged"),
        (base(120, 24, 2, 2, 5).sparse(120, 24, 4), "csr even"),
        (base(85, 19, 2, 3, 5).sparse(85, 19, 5), "csr ragged"),
    ];
    for (b, label) in shapes {
        let cfg = || b.clone().build().unwrap();
        let mut full = Trainer::new(cfg()).unwrap();
        let a = full.run().unwrap();
        for t_mid in [0usize, 2, 5] {
            let mut first = Trainer::new(cfg()).unwrap();
            for _ in 0..t_mid {
                first.step().unwrap();
            }
            // force the snapshot through its serialized form — resuming
            // from in-memory state would not test the format
            let text = first.checkpoint().to_json().to_string_pretty();
            let snap = RunState::from_json(&Value::parse(&text).unwrap()).unwrap();
            let mut second = Trainer::resume(cfg(), snap).unwrap();
            assert_eq!(second.iteration(), t_mid, "{label}: resume lost the iteration count");
            let o = if second.is_done() { second.outcome() } else { second.run().unwrap() };
            let lb = format!("{label}, checkpointed at t={t_mid}");
            assert_eq!(a.w, o.w, "{lb}: final iterate diverged");
            assert_same_trajectory(&a.history, &o.history, &lb);
            assert_eq!(a.comm_bytes, o.comm_bytes, "{lb}: wire accounting diverged");
            assert_eq!(a.comm_msgs, o.comm_msgs, "{lb}: message accounting diverged");
        }
    }
}

/// The combined headline: kill workers *and* interrupt/resume the run —
/// still bit-identical to the pristine uninterrupted, fault-free run.
#[test]
fn faulted_interrupted_run_still_matches_the_pristine_one() {
    let _g = locked();
    for kind in [ExecutorKind::InProcess, ExecutorKind::Threaded] {
        let cfg = || base(90, 18, 2, 2, 6).executor(kind).build().unwrap();
        let mut pristine = Trainer::new(cfg()).unwrap();
        pristine.set_fault_plan(None);
        let a = pristine.run().unwrap();

        let plan = FaultPlan::seeded(77, 2, 4, 6);
        let mut first = Trainer::new(cfg()).unwrap();
        first.set_fault_plan(Some(plan.clone()));
        for _ in 0..3 {
            first.step().unwrap();
        }
        let mut second = Trainer::resume(cfg(), first.checkpoint()).unwrap();
        second.set_fault_plan(Some(plan.clone()));
        let o = second.run().unwrap();

        let label = format!("{kind} plan=[{plan}]");
        assert_eq!(a.w, o.w, "{label}: final iterate diverged");
        assert_same_trajectory(&a.history, &o.history, &label);
    }
}

/// A checkpoint is executor-agnostic: a snapshot taken under one
/// transport resumes under the other and reproduces the uninterrupted
/// trajectory bit-for-bit — `RunState::executor` is provenance, not a
/// constraint.
#[test]
fn checkpoints_resume_across_executors() {
    let _g = locked();
    let cfg = |kind| base(90, 18, 2, 2, 6).executor(kind).build().unwrap();
    let mut full = Trainer::new(cfg(ExecutorKind::InProcess)).unwrap();
    let a = full.run().unwrap();
    let pairs = [
        (ExecutorKind::InProcess, ExecutorKind::Threaded),
        (ExecutorKind::Threaded, ExecutorKind::InProcess),
    ];
    for (from, to) in pairs {
        let mut first = Trainer::new(cfg(from)).unwrap();
        for _ in 0..3 {
            first.step().unwrap();
        }
        // through the serialized form, as a real cross-machine move would go
        let text = first.checkpoint().to_json().to_string_pretty();
        let snap = RunState::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(snap.executor, from, "snapshot must record its provenance");
        let mut second = Trainer::resume(cfg(to), snap).unwrap();
        assert_eq!(second.executor(), to);
        let o = second.run().unwrap();
        let label = format!("{from} -> {to}");
        assert_eq!(a.w, o.w, "{label}: final iterate diverged");
        assert_same_trajectory(&a.history, &o.history, &label);
        assert_eq!(a.comm_bytes, o.comm_bytes, "{label}: wire accounting diverged");
    }
}

#[test]
fn run_with_checkpoints_leaves_a_resumable_file() {
    let _g = locked();
    let dir = std::env::temp_dir().join(format!("sodda-ckpt-{}", std::process::id()));
    let path = dir.join("run.json");
    let cfg = || base(80, 16, 2, 2, 5).build().unwrap();
    let mut t = Trainer::new(cfg()).unwrap();
    let a = t.run_with_checkpoints(&path, 2).unwrap();
    let snap = RunState::load(&path).unwrap();
    assert_eq!(snap.t, 5, "final checkpoint must capture the completed run");
    let resumed = Trainer::resume(cfg(), snap).unwrap();
    assert!(resumed.is_done());
    assert_eq!(resumed.weights(), &a.w[..]);
    assert_same_trajectory(&a.history, resumed.history(), "run_with_checkpoints");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- SODDA_FAULT_PLAN plumbing ---------------------------------------------

#[test]
fn env_plan_is_staged_and_applied() {
    let auto = || base(80, 16, 2, 2, 3).build().unwrap();
    with_plan_env(Some("1@2:grad"), || {
        let mut t = Trainer::new(auto()).unwrap();
        let expect: FaultPlan = "1@2:grad".parse().unwrap();
        assert_eq!(t.fault_plan(), Some(&expect), "staging must pick up the env plan");
        t.run().unwrap();
        assert_eq!(t.history().faults.len(), 1);
        assert_eq!(t.history().faults[0].worker, 1);
        assert_eq!(t.history().faults[0].iter, 2);
    });
    with_plan_env(None, || {
        assert!(Trainer::new(auto()).unwrap().fault_plan().is_none());
    });
    with_plan_env(Some("   "), || {
        assert!(Trainer::new(auto()).unwrap().fault_plan().is_none(), "blank means unset");
    });
}

#[test]
fn malformed_env_plan_is_a_staging_error() {
    with_plan_env(Some("2@3:outer"), || {
        let err = Trainer::new(base(80, 16, 2, 2, 3).build().unwrap()).unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains(FAULT_PLAN_ENV), "unhelpful error: {chain}");
    });
}

#[test]
fn set_fault_plan_overrides_the_env() {
    with_plan_env(Some("0@1:mu"), || {
        let mut t = Trainer::new(base(80, 16, 2, 2, 3).build().unwrap()).unwrap();
        t.set_fault_plan(None);
        t.run().unwrap();
        assert!(t.history().faults.is_empty(), "cleared plan must not fire");
    });
}
