//! `cargo xtask`-style repo tooling. One subcommand so far:
//!
//! ```text
//! cargo run -p xtask -- lint [--root <repo-root>]
//! ```
//!
//! runs the repo-invariant lint pass (see [`lints`]) over the tree and
//! exits non-zero listing every violation. CI runs it in the main
//! `rust` lane; the lints themselves are unit-tested against seeded
//! violations in `lints.rs`.

use std::path::PathBuf;
use std::process::ExitCode;

mod lints;

fn usage() -> ExitCode {
    eprintln!("usage: cargo run -p xtask -- lint [--root <repo-root>]");
    ExitCode::FAILURE
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut it = args.iter();
    match it.next().map(String::as_str) {
        Some("lint") => {}
        _ => return usage(),
    }
    let root = match (it.next().map(String::as_str), it.next()) {
        (None, _) => {
            // xtask lives at <repo>/rust/xtask
            let mut p = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
            p.pop();
            p.pop();
            p
        }
        (Some("--root"), Some(path)) => PathBuf::from(path),
        _ => return usage(),
    };

    let outcome = match lints::run(&root) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("xtask lint: failed to scan {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    if outcome.violations.is_empty() {
        println!(
            "xtask lint: OK — {} files, {} lints, 0 violations",
            outcome.files_scanned,
            lints::LINT_NAMES.len()
        );
        ExitCode::SUCCESS
    } else {
        for v in &outcome.violations {
            eprintln!("{v}");
        }
        eprintln!(
            "xtask lint: {} violation(s) across {} files (waive a line with \
             `lint:allow(<name>)` in a comment on or above it)",
            outcome.violations.len(),
            outcome.files_scanned
        );
        ExitCode::FAILURE
    }
}
