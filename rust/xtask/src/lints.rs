//! Repo-invariant lints: properties of *this* codebase that clippy
//! cannot express, enforced lexically over a comment-and-string
//! scrubbed view of the tree.
//!
//! | lint | invariant |
//! |------|-----------|
//! | `hash_containers` | no `HashMap`/`HashSet` in `train/`, `cluster/`, `engine/` — hash iteration order is nondeterministic and those are the modules the bit-for-bit determinism contract covers |
//! | `config_literal` | `ExperimentConfig` is only struct-literal-constructed inside `config/` — everyone else goes through the validating builder |
//! | `raw_env` | no `std::env::var`/`set_var`/`remove_var` outside `util/env.rs` — the sanctioned module is what makes env-mutating tests race-free |
//! | `steady_alloc` | `train/step.rs` never calls the allocating (non-`_into`) cluster/engine entry points — the steady state is allocation-free by budget |
//! | `wildcard_cmd` | `WorkerCore::execute` has no wildcard `Cmd` arm — adding a command must force every transport-visible match to be revisited |
//! | `doc_refs` | backticked path references in README/ROADMAP/CHANGES and `//!` module docs point at files that exist |
//! | `doc_contract` | the determinism-contract and checkpoint-durability doc sections, the README fault-tolerance subsections, and the CI lanes that enforce them stay present |
//!
//! Any flagged line can be waived with `lint:allow(<name>)` in a
//! comment on the same line or the line above — waivers are meant to
//! be rare and self-justifying (say *why* next to the tag).
//!
//! Every lint has a fixture test below proving it fires on a seeded
//! violation and stays quiet on the conforming shape, so a lint that
//! silently stops matching is a test failure, not a blind spot.

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

/// Names, in report order — `main.rs` prints the count.
pub const LINT_NAMES: [&str; 7] = [
    "hash_containers",
    "config_literal",
    "raw_env",
    "steady_alloc",
    "wildcard_cmd",
    "doc_refs",
    "doc_contract",
];

pub struct Outcome {
    pub files_scanned: usize,
    pub violations: Vec<Violation>,
}

pub struct Violation {
    pub lint: &'static str,
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.lint, self.msg)
    }
}

/// One scanned file: the raw text (waivers, docs, markdown) and, for
/// Rust sources, a scrubbed view with comment and string/char-literal
/// contents blanked to spaces (newlines kept, so line numbers agree).
struct LintFile {
    path: String,
    raw_lines: Vec<String>,
    scrubbed: String,
}

impl LintFile {
    fn scrubbed_lines(&self) -> impl Iterator<Item = (usize, &str)> {
        self.scrubbed.lines().enumerate()
    }

    fn is_rust(&self) -> bool {
        self.path.ends_with(".rs")
    }
}

fn lint_file(path: &str, text: &str) -> LintFile {
    let scrubbed = if path.ends_with(".rs") { scrub_rust(text) } else { text.to_string() };
    LintFile {
        path: path.to_string(),
        raw_lines: text.lines().map(str::to_string).collect(),
        scrubbed,
    }
}

/// Run every lint over the tree rooted at `root` (the repo root).
pub fn run(root: &Path) -> io::Result<Outcome> {
    let files = collect(root)?;
    let mut violations = Vec::new();
    violations.extend(hash_containers(&files));
    violations.extend(config_literal(&files));
    violations.extend(raw_env(&files));
    violations.extend(steady_alloc(&files));
    violations.extend(wildcard_cmd(&files));
    violations.extend(doc_refs(root, &files));
    violations.extend(doc_contract(&files));
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(Outcome { files_scanned: files.len(), violations })
}

// ---------------------------------------------------------------- collect --

/// Rust sources under these roots are linted; `rust/xtask` itself is
/// deliberately out of scope (its fixtures *contain* seeded
/// violations).
const RS_DIRS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];
const TEXT_FILES: [&str; 4] =
    ["README.md", "ROADMAP.md", "CHANGES.md", ".github/workflows/ci.yml"];

fn collect(root: &Path) -> io::Result<Vec<LintFile>> {
    let mut out = Vec::new();
    for dir in RS_DIRS {
        walk(root, &root.join(dir), &mut out)?;
    }
    for name in TEXT_FILES {
        let p = root.join(name);
        if p.is_file() {
            out.push(lint_file(name, &fs::read_to_string(&p)?));
        }
    }
    Ok(out)
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<LintFile>) -> io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?.iter().map(|e| e.path()).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .expect("walked path is under root")
                .to_string_lossy()
                .replace('\\', "/");
            out.push(lint_file(&rel, &fs::read_to_string(&path)?));
        }
    }
    Ok(())
}

// --------------------------------------------------------------- scrubber --

/// Blank comments, string/char-literal contents, raw strings and byte
/// strings to spaces, preserving newlines (and therefore line
/// numbers). Lifetimes (`'a`) survive; `'x'` char literals do not.
fn scrub_rust(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let ident = |c: char| c.is_alphanumeric() || c == '_';
    while i < n {
        let c = b[i];
        // line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // block comment, nesting tracked
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 0;
            while i < n {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        let prev_ident = i > 0 && ident(b[i - 1]);
        // raw (and raw byte) strings: r"..."  r#"..."#  br"..."
        if (c == 'r' || c == 'b') && !prev_ident {
            let mut j = i;
            if b[j] == 'b' && j + 1 < n && b[j + 1] == 'r' {
                j += 1;
            }
            if b[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0;
                while k < n && b[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && b[k] == '"' {
                    for _ in i..=k {
                        out.push(' ');
                    }
                    i = k + 1;
                    while i < n {
                        if b[i] == '"' {
                            let mut h = 0;
                            while h < hashes && i + 1 + h < n && b[i + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                for _ in 0..=hashes {
                                    out.push(' ');
                                }
                                i += 1 + hashes;
                                break;
                            }
                        }
                        out.push(blank(b[i]));
                        i += 1;
                    }
                    continue;
                }
            }
            // plain byte string b"..." — blank the `b`, let the next
            // iteration handle the opening quote
            if c == 'b' && i + 1 < n && b[i + 1] == '"' {
                out.push(' ');
                i += 1;
                continue;
            }
        }
        // ordinary string literal
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' {
                    out.push(' ');
                    i += 1;
                    if i < n {
                        out.push(blank(b[i]));
                        i += 1;
                    }
                } else if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // lifetime vs char literal
        if c == '\'' {
            let lifetime = i + 1 < n
                && (b[i + 1].is_alphabetic() || b[i + 1] == '_')
                && !(i + 2 < n && b[i + 2] == '\'');
            if lifetime {
                out.push('\'');
                i += 1;
                continue;
            }
            out.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' {
                    out.push(' ');
                    i += 1;
                    if i < n {
                        out.push(blank(b[i]));
                        i += 1;
                    }
                } else if b[i] == '\'' {
                    out.push(' ');
                    i += 1;
                    break;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

// ---------------------------------------------------------------- helpers --

/// `lint:allow(<name>)` on the flagged line or the one above it.
fn waived(file: &LintFile, line_idx: usize, lint: &str) -> bool {
    let tag = format!("lint:allow({lint})");
    let on = |idx: usize| file.raw_lines.get(idx).is_some_and(|l| l.contains(&tag));
    on(line_idx) || (line_idx > 0 && on(line_idx - 1))
}

/// Byte offset of `word` in `line` with identifier boundaries on both
/// sides, or `None`.
fn find_word(line: &str, word: &str) -> Option<usize> {
    let ident = |c: char| c.is_alphanumeric() || c == '_';
    for (pos, _) in line.match_indices(word) {
        let before_ok = !line[..pos].chars().next_back().is_some_and(ident);
        let after_ok = !line[pos + word.len()..].chars().next().is_some_and(ident);
        if before_ok && after_ok {
            return Some(pos);
        }
    }
    None
}

fn violation(lint: &'static str, file: &LintFile, line_idx: usize, msg: String) -> Violation {
    Violation { lint, file: file.path.clone(), line: line_idx + 1, msg }
}

// ------------------------------------------------------------------ lints --

/// Directories covered by the determinism contract: everything a
/// computed number flows through.
const HOT_DIRS: [&str; 3] = ["rust/src/train/", "rust/src/cluster/", "rust/src/engine/"];

fn hash_containers(files: &[LintFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files {
        if !f.is_rust() || !HOT_DIRS.iter().any(|d| f.path.starts_with(d)) {
            continue;
        }
        for (idx, line) in f.scrubbed_lines() {
            for word in ["HashMap", "HashSet"] {
                if find_word(line, word).is_some() && !waived(f, idx, "hash_containers") {
                    out.push(violation(
                        "hash_containers",
                        f,
                        idx,
                        format!(
                            "`{word}` in a determinism-contract module: hash iteration \
                             order is nondeterministic. Use a Vec/sorted structure, or \
                             waive with lint:allow(hash_containers) if it is only ever \
                             membership-tested"
                        ),
                    ));
                }
            }
        }
    }
    out
}

fn config_literal(files: &[LintFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files {
        if !f.is_rust() || f.path.starts_with("rust/src/config/") {
            continue;
        }
        for (idx, line) in f.scrubbed_lines() {
            let Some(pos) = find_word(line, "ExperimentConfig") else { continue };
            let after = line[pos + "ExperimentConfig".len()..].trim_start();
            if !after.starts_with('{') {
                continue;
            }
            let before = &line[..pos];
            // `-> ExperimentConfig {`, `impl ExperimentConfig {` and
            // friends are type positions, not construction
            if before.contains("->") || before.contains("impl") || before.contains("struct") {
                continue;
            }
            if !waived(f, idx, "config_literal") {
                out.push(violation(
                    "config_literal",
                    f,
                    idx,
                    "`ExperimentConfig { .. }` struct literal outside config/: construct \
                     through `ExperimentConfig::builder()` so validation cannot be skipped"
                        .to_string(),
                ));
            }
        }
    }
    out
}

const ENV_PATTERNS: [&str; 3] = ["env::var", "env::set_var", "env::remove_var"];
const ENV_MODULE: &str = "rust/src/util/env.rs";

fn raw_env(files: &[LintFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files {
        if !f.is_rust() || f.path == ENV_MODULE {
            continue;
        }
        for (idx, line) in f.scrubbed_lines() {
            for pat in ENV_PATTERNS {
                if line.contains(pat) && !waived(f, idx, "raw_env") {
                    out.push(violation(
                        "raw_env",
                        f,
                        idx,
                        format!(
                            "raw `{pat}` outside util/env.rs: go through \
                             `util::env::read`/`set`/`unset`/`ScopedEnv` so env access \
                             stays serialized under the shared test lock"
                        ),
                    ));
                }
            }
        }
    }
    out
}

/// Allocating (non-`_into`, non-pooled) cluster/engine entry points
/// that must not appear in the steady-state step. `block_loss` is
/// absent on purpose: it reduces to a scalar through the leader pool.
const ALLOC_CALLS: [&str; 10] = [
    ".partial_z(",
    ".partial_z_cols(",
    ".partial_u(",
    ".partial_u_cols(",
    ".grad(",
    ".grad_cols(",
    ".grad_slice(",
    ".svrg(",
    ".svrg_inner(",
    ".svrg_inner_avg(",
];
const STEP_FILE: &str = "rust/src/train/step.rs";

fn steady_alloc(files: &[LintFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files {
        if f.path != STEP_FILE {
            continue;
        }
        for (idx, line) in f.scrubbed_lines() {
            for pat in ALLOC_CALLS {
                if line.contains(pat) && !waived(f, idx, "steady_alloc") {
                    out.push(violation(
                        "steady_alloc",
                        f,
                        idx,
                        format!(
                            "allocating entry point `{pat}..)` in the steady-state step: \
                             use the pooled `_into` variant (the alloc-regression gate \
                             budgets ~7 allocations per outer iteration)"
                        ),
                    ));
                }
            }
        }
    }
    out
}

const TRANSPORT_MOD: &str = "rust/src/cluster/transport/mod.rs";

/// `WorkerCore::execute` must match `Cmd` exhaustively by name: a new
/// command variant has to be a compile error at every transport-visible
/// match, not silently swallowed by `_ =>`.
fn wildcard_cmd(files: &[LintFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    let Some(f) = files.iter().find(|f| f.path == TRANSPORT_MOD) else {
        return out;
    };
    let text = &f.scrubbed;
    let Some(fn_pos) = text.find("fn execute") else {
        out.push(Violation {
            lint: "wildcard_cmd",
            file: f.path.clone(),
            line: 1,
            msg: "expected `fn execute` in transport/mod.rs — if WorkerCore::execute moved \
                  or was renamed, update the wildcard_cmd lint so it keeps guarding the \
                  Cmd match"
                .to_string(),
        });
        return out;
    };
    let bytes: Vec<char> = text[fn_pos..].chars().collect();
    // span of the function body: first '{' after the signature to its
    // matching '}'
    let mut depth = 0usize;
    let mut body_end = bytes.len();
    let mut started = false;
    let mut k = 0;
    while k < bytes.len() {
        match bytes[k] {
            '{' => {
                depth += 1;
                started = true;
            }
            '}' => {
                depth = depth.saturating_sub(1);
                if started && depth == 0 {
                    body_end = k;
                    break;
                }
            }
            _ => {}
        }
        k += 1;
    }
    let ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut j = 0;
    while j < body_end {
        if bytes[j] == '_'
            && (j == 0 || !ident(bytes[j - 1]))
            && (j + 1 >= bytes.len() || !ident(bytes[j + 1]))
        {
            let mut t = j + 1;
            while t < bytes.len() && bytes[t].is_whitespace() {
                t += 1;
            }
            if t + 1 < bytes.len() && bytes[t] == '=' && bytes[t + 1] == '>' {
                let line_idx =
                    text[..fn_pos].matches('\n').count() + bytes[..j].iter().filter(|&&c| c == '\n').count();
                if !waived(f, line_idx, "wildcard_cmd") {
                    out.push(violation(
                        "wildcard_cmd",
                        f,
                        line_idx,
                        "wildcard `_ =>` arm inside WorkerCore::execute: match every Cmd \
                         variant by name so adding a command forces this site to be \
                         revisited"
                            .to_string(),
                    ));
                }
            }
        }
        j += 1;
    }
    out
}

const DOC_EXTS: [&str; 9] =
    [".rs", ".md", ".json", ".toml", ".yml", ".yaml", ".py", ".txt", ".sh"];

/// Does a backticked token look like a path reference this repo should
/// contain? Conservative on purpose: flags only slash-paths with a
/// known extension (or trailing `/`) and bare `*.md` names.
fn path_candidate(tok: &str) -> bool {
    if tok.is_empty() || tok.len() > 100 || tok.chars().any(char::is_whitespace) {
        return false;
    }
    const NON_PATH: [&str; 12] =
        ["<", ">", "(", ")", "{", "}", "*", "|", "=", "::", "#", "@"];
    if NON_PATH.iter().any(|b| tok.contains(b)) {
        return false;
    }
    if tok.starts_with('/') || tok.starts_with('-') || tok.starts_with("http") {
        return false;
    }
    // build outputs and AOT artifact bundles are legitimately
    // referenced in docs but never checked in
    if tok.starts_with("target/") || tok.starts_with("artifacts/") {
        return false;
    }
    if tok.contains('/') {
        tok.ends_with('/') || DOC_EXTS.iter().any(|e| tok.ends_with(e))
    } else {
        tok.ends_with(".md")
    }
}

/// Backticked inline-code spans on one line (fenced blocks are the
/// caller's concern).
fn inline_code_spans(line: &str) -> Vec<&str> {
    line.split('`').skip(1).step_by(2).collect()
}

fn doc_refs(root: &Path, files: &[LintFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in files {
        // (doc line index, text, resolution base for relative refs)
        let doc_lines: Vec<(usize, &str)> = if f.path.ends_with(".md") {
            f.raw_lines.iter().enumerate().map(|(i, l)| (i, l.as_str())).collect()
        } else if f.is_rust() {
            f.raw_lines
                .iter()
                .enumerate()
                .filter_map(|(i, l)| {
                    l.trim_start().strip_prefix("//!").map(|rest| (i, rest))
                })
                .collect()
        } else {
            continue;
        };
        let file_dir = Path::new(&f.path).parent().map(|d| root.join(d));
        let mut in_fence = false;
        for (idx, text) in doc_lines {
            if text.trim_start().starts_with("```") {
                in_fence = !in_fence;
                continue;
            }
            if in_fence {
                continue;
            }
            for tok in inline_code_spans(text) {
                if !path_candidate(tok) || waived(f, idx, "doc_refs") {
                    continue;
                }
                let mut bases =
                    vec![root.to_path_buf(), root.join("rust"), root.join("rust/src")];
                if let Some(d) = &file_dir {
                    bases.push(d.clone());
                }
                if bases.iter().any(|b| b.join(tok).exists()) {
                    continue;
                }
                out.push(violation(
                    "doc_refs",
                    f,
                    idx,
                    format!(
                        "doc reference `{tok}` does not resolve against the repo root, \
                         rust/, rust/src/, or this file's directory — fix the path or \
                         waive with lint:allow(doc_refs)"
                    ),
                ));
            }
        }
    }
    out
}

const CONTRACT_HEADING: &str = "## Determinism contract";
const CHECKPOINT_MOD: &str = "rust/src/train/checkpoint.rs";
const CI_FILE: &str = ".github/workflows/ci.yml";
const CI_LANES: [&str; 5] = ["rust-async:", "rust-loom:", "rust-tsan:", "rust-miri:", "xtask"];

/// The correctness-tooling docs and CI lanes reference each other;
/// this keeps any of them from quietly disappearing in a refactor.
fn doc_contract(files: &[LintFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut require = |path: &str, needle: &str, msg: &str| {
        match files.iter().find(|f| f.path == path) {
            Some(f) if f.raw_lines.iter().any(|l| l.contains(needle)) => {}
            Some(f) => out.push(Violation {
                lint: "doc_contract",
                file: f.path.clone(),
                line: 1,
                msg: msg.to_string(),
            }),
            None => out.push(Violation {
                lint: "doc_contract",
                file: path.to_string(),
                line: 1,
                msg: format!("file missing from the tree: {msg}"),
            }),
        }
    };
    require(
        TRANSPORT_MOD,
        CONTRACT_HEADING,
        "the `## Determinism contract` section is gone from the transport module docs — \
         it is the normative statement the executor-equivalence, loom and TSan lanes \
         enforce; move it, don't delete it (and update this lint)",
    );
    require(
        "README.md",
        "eterminism contract",
        "README no longer references the determinism contract (see \
         cluster/transport/mod.rs) — the correctness-tooling section must point at it",
    );
    for lane in CI_LANES {
        require(
            CI_FILE,
            lane,
            &format!("CI lane `{lane}` disappeared from the workflow — the correctness \
                      tooling (loom/TSan/Miri/xtask) must stay wired into CI"),
        );
    }
    require(
        "README.md",
        "### Escalation, permanent loss & live re-sharding",
        "README lost the escalation/re-sharding subsection — RecoveryPolicy and the \
         elastic-degradation behavior must stay documented under Fault tolerance",
    );
    require(
        "README.md",
        "### Durable checkpoints",
        "README lost the durable-checkpoints subsection — atomic saves and the \
         incremental delta mode must stay documented under Checkpoint / resume",
    );
    require(
        CHECKPOINT_MOD,
        "## Durability",
        "the `## Durability` section is gone from the checkpoint module docs — it is \
         the normative statement of atomic saves and the delta format; move it, don't \
         delete it (and update this lint)",
    );
    require(
        CI_FILE,
        "grad!perm",
        "the permanent-loss fault lane (a `!perm` plan entry) disappeared from the CI \
         matrix — escalation + live re-sharding must stay exercised on both executors",
    );
    require(
        "README.md",
        "### Bounded-staleness aggregation",
        "README lost the bounded-staleness subsection — the quorum/timeout/late-fold \
         semantics and the barrier-freeze guarantee must stay documented under Fault \
         tolerance",
    );
    require(
        CI_FILE,
        "SODDA_STALENESS",
        "the bounded-staleness lane (a `SODDA_STALENESS` quorum policy) disappeared \
         from the CI matrix — quorum aggregation must stay exercised on both executors",
    );
    out
}

// ------------------------------------------------------------------ tests --

#[cfg(test)]
mod tests {
    use super::*;

    fn files(spec: &[(&str, &str)]) -> Vec<LintFile> {
        spec.iter().map(|(p, t)| lint_file(p, t)).collect()
    }

    // -- scrubber --

    #[test]
    fn scrubber_blanks_comments_strings_and_chars_but_not_code() {
        let src = "let a = \"HashMap\"; // HashMap\nlet b = 'H'; /* HashMap */ let c = HashMap::new();\n";
        let s = scrub_rust(src);
        assert_eq!(s.lines().count(), 2);
        assert!(!s.lines().next().unwrap().contains("HashMap"), "{s}");
        assert!(s.lines().nth(1).unwrap().contains("HashMap::new"), "{s}");
        assert_eq!(s.lines().nth(1).unwrap().matches("HashMap").count(), 1, "{s}");
    }

    #[test]
    fn scrubber_handles_raw_strings_escapes_and_lifetimes() {
        let src = r####"let r = r#"env::var "quoted" inside"#; let s = "esc \" env::var";
fn f<'a>(x: &'a str) -> &'a str { x }
let c = '"'; let d = b"env::var"; let e = br#"env::var"#; let done = 1;
"####;
        let s = scrub_rust(src);
        assert!(!s.contains("env::var"), "{s}");
        assert!(s.contains("<'a>"), "lifetimes must survive: {s}");
        assert!(s.contains("&'a str"), "{s}");
        assert!(s.contains("let done = 1;"), "code after literals must survive: {s}");
    }

    #[test]
    fn scrubber_handles_nested_block_comments() {
        let s = scrub_rust("a /* x /* HashSet */ y */ b = HashSet;\n");
        assert_eq!(s.matches("HashSet").count(), 1, "{s}");
        assert!(s.contains("b = HashSet;"), "{s}");
    }

    // -- hash_containers --

    #[test]
    fn hash_containers_fires_in_hot_dirs_only() {
        let fs = files(&[
            ("rust/src/train/step2.rs", "use std::collections::HashMap;\n"),
            ("rust/src/data/synth2.rs", "use std::collections::HashMap;\n"),
        ]);
        let v = hash_containers(&fs);
        assert_eq!(v.len(), 1, "{:?}", v.iter().map(|v| v.to_string()).collect::<Vec<_>>());
        assert_eq!(v[0].file, "rust/src/train/step2.rs");
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn hash_containers_respects_waivers_and_scrubbing() {
        let fs = files(&[(
            "rust/src/engine/x.rs",
            "// lint:allow(hash_containers): membership only\nlet s: HashSet<u32> = x;\nlet msg = \"HashSet\";\n",
        )]);
        assert!(hash_containers(&fs).is_empty());
        let fs = files(&[("rust/src/engine/x.rs", "let s: HashSet<u32> = x; // lint:allow(hash_containers)\n")]);
        assert!(hash_containers(&fs).is_empty());
    }

    #[test]
    fn hash_containers_needs_word_boundary() {
        let fs = files(&[("rust/src/cluster/x.rs", "struct MyHashMapLike; let HashMapper = 1;\n")]);
        assert!(hash_containers(&fs).is_empty());
    }

    // -- config_literal --

    #[test]
    fn config_literal_fires_on_construction_outside_config() {
        let fs = files(&[("rust/src/train/x.rs", "let c = ExperimentConfig { p: 1 };\n")]);
        let v = config_literal(&fs);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn config_literal_ignores_type_positions_and_config_module() {
        let fs = files(&[
            ("rust/src/train/x.rs", "fn cfg() -> ExperimentConfig {\n"),
            ("rust/src/train/y.rs", "impl ExperimentConfig {\n"),
            ("rust/src/config/presets.rs", "let c = ExperimentConfig { p: 1 };\n"),
            ("rust/tests/z.rs", "fn base(n: usize) -> ExperimentConfig {\n"),
        ]);
        assert!(config_literal(&fs).is_empty());
    }

    // -- raw_env --

    #[test]
    fn raw_env_fires_outside_the_sanctioned_module() {
        let fs = files(&[
            ("rust/src/train/x.rs", "let v = std::env::var(\"SODDA_EXECUTOR\");\n"),
            ("rust/tests/t.rs", "std::env::set_var(\"A\", \"1\");\nstd::env::remove_var(\"A\");\n"),
            ("rust/src/util/env.rs", "std::env::var(name).ok()\n"),
        ]);
        let v = raw_env(&fs);
        assert_eq!(v.len(), 3, "{:?}", v.iter().map(|v| v.to_string()).collect::<Vec<_>>());
        assert!(v.iter().all(|v| v.file != "rust/src/util/env.rs"));
    }

    #[test]
    fn raw_env_allows_sanctioned_calls_and_strings() {
        let fs = files(&[(
            "rust/src/train/x.rs",
            "let v = crate::util::env::read(\"X\");\nsodda::util::env::unset(k);\nlet s = \"env::var\";\n",
        )]);
        assert!(raw_env(&fs).is_empty());
    }

    // -- steady_alloc --

    #[test]
    fn steady_alloc_fires_only_in_step_rs_and_only_on_allocating_names() {
        let fs = files(&[(
            "rust/src/train/step.rs",
            "let z = cluster.partial_u(&w, &rows);\nlet ok = cluster.partial_u_cols_into(&w, &mut buf);\nlet l = cluster.block_loss(&w, &rows);\n",
        )]);
        let v = steady_alloc(&fs);
        assert_eq!(v.len(), 1, "{:?}", v.iter().map(|v| v.to_string()).collect::<Vec<_>>());
        assert_eq!(v[0].line, 1);

        let fs = files(&[("rust/src/train/outer.rs", "let z = cluster.partial_u(&w, &rows);\n")]);
        assert!(steady_alloc(&fs).is_empty(), "other files may call allocating APIs");
    }

    // -- wildcard_cmd --

    const EXEC_OK: &str = "pub(crate) fn execute(&mut self, cmd: Cmd) -> Option<Reply> {\n    let reply = match cmd {\n        Cmd::Shutdown | Cmd::Die | Cmd::Nop => return None,\n    };\n    Some(reply)\n}\nfn after() { match x { _ => 1 } }\n";

    #[test]
    fn wildcard_cmd_accepts_exhaustive_match_and_ignores_other_fns() {
        let fs = files(&[(TRANSPORT_MOD, EXEC_OK)]);
        assert!(wildcard_cmd(&fs).is_empty());
    }

    #[test]
    fn wildcard_cmd_fires_on_a_seeded_wildcard_arm() {
        let seeded = EXEC_OK.replace("Cmd::Shutdown | Cmd::Die | Cmd::Nop => return None", "_ => return None");
        let fs = files(&[(TRANSPORT_MOD, &seeded)]);
        let v = wildcard_cmd(&fs);
        assert_eq!(v.len(), 1, "{:?}", v.iter().map(|v| v.to_string()).collect::<Vec<_>>());
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn wildcard_cmd_fires_when_execute_is_missing() {
        let fs = files(&[(TRANSPORT_MOD, "fn run() {}\n")]);
        let v = wildcard_cmd(&fs);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("renamed"), "{}", v[0].msg);
    }

    #[test]
    fn wildcard_cmd_ignores_underscore_bindings() {
        let src = "fn execute(&mut self) {\n    let _ = tx.send(x);\n    let _unused = 1;\n    match c { Cmd::Nop => {} }\n}\n";
        let fs = files(&[(TRANSPORT_MOD, src)]);
        assert!(wildcard_cmd(&fs).is_empty());
    }

    // -- doc_refs --

    #[test]
    fn doc_refs_flags_ghost_paths_and_accepts_real_ones() {
        let root = std::env::temp_dir().join("xtask-docref-fixture");
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(root.join("rust/src")).unwrap();
        fs::write(root.join("rust/src/lib.rs"), "pub fn x() {}\n").unwrap();
        let fs_ = files(&[(
            "README.md",
            "see `src/lib.rs` and `src/ghost.rs` for details\n```\ncode `src/also_ghost.rs` in a fence\n```\nplain `not-a-path` and `A × B` and `1/f` stay quiet\n",
        )]);
        let v = doc_refs(&root, &fs_);
        assert_eq!(v.len(), 1, "{:?}", v.iter().map(|v| v.to_string()).collect::<Vec<_>>());
        assert!(v[0].msg.contains("src/ghost.rs"), "{}", v[0].msg);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn doc_refs_reads_module_docs_and_resolves_relative_to_the_file() {
        let root = std::env::temp_dir().join("xtask-docref-moddoc");
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(root.join("rust/src/cluster/transport")).unwrap();
        fs::write(root.join("rust/src/cluster/transport/sync.rs"), "").unwrap();
        // `transport/sync.rs` resolves only against the doc file's own
        // directory, not the root/rust/rust-src bases
        let good =
            files(&[("rust/src/cluster/mod.rs", "//! see `transport/sync.rs` for the shim\n")]);
        assert!(doc_refs(&root, &good).is_empty());
        let bad = files(&[("rust/src/cluster/mod.rs", "//! see `gone/away.rs` for nothing\n")]);
        assert_eq!(doc_refs(&root, &bad).len(), 1);
        // bare names without a slash are not path candidates — too many
        // false positives (`main.rs`-style prose mentions)
        let bare = files(&[("rust/src/cluster/mod.rs", "//! see `nonexistent.rs`\n")]);
        assert!(doc_refs(&root, &bare).is_empty());
        let _ = fs::remove_dir_all(&root);
    }

    // -- doc_contract --

    fn contract_files() -> Vec<LintFile> {
        files(&[
            (TRANSPORT_MOD, "//! ## Determinism contract\nfn execute() {}\n"),
            (
                "README.md",
                "the determinism contract lives in the transport docs\n\
                 ### Escalation, permanent loss & live re-sharding\n\
                 ### Durable checkpoints\n\
                 ### Bounded-staleness aggregation\n",
            ),
            (CI_FILE, "jobs:\n  rust-async:\n    SODDA_STALENESS: \"0.75:2:4\"\n  rust-loom:\n  rust-tsan:\n  rust-miri:\n  x:\n    run: cargo run -p xtask -- lint\n    plan: \"1@2:grad!perm\"\n"),
            (CHECKPOINT_MOD, "//! ## Durability\nfn save() {}\n"),
        ])
    }

    #[test]
    fn doc_contract_passes_when_everything_is_wired() {
        assert!(doc_contract(&contract_files()).is_empty());
    }

    #[test]
    fn doc_contract_fires_when_the_heading_or_a_lane_vanishes() {
        let mut fs_ = contract_files();
        fs_[0] = lint_file(TRANSPORT_MOD, "//! no contract here\nfn execute() {}\n");
        assert_eq!(doc_contract(&fs_).len(), 1);

        let mut fs_ = contract_files();
        fs_[2] = lint_file(
            CI_FILE,
            "jobs:\n  rust-async:\n    SODDA_STALENESS: \"0.75:2:4\"\n  rust-loom:\n  \
             rust-miri:\n    run: xtask\n    plan: \"1@2:grad!perm\"\n",
        );
        let v = doc_contract(&fs_);
        assert_eq!(v.len(), 1, "{:?}", v.iter().map(|v| v.to_string()).collect::<Vec<_>>());
        assert!(v[0].msg.contains("rust-tsan"), "{}", v[0].msg);

        let mut fs_ = contract_files();
        fs_[3] = lint_file(CHECKPOINT_MOD, "//! just a module\nfn save() {}\n");
        let v = doc_contract(&fs_);
        assert_eq!(v.len(), 1, "{:?}", v.iter().map(|v| v.to_string()).collect::<Vec<_>>());
        assert!(v[0].msg.contains("Durability"), "{}", v[0].msg);
    }

    // -- end to end on this repo --

    #[test]
    fn the_real_tree_is_lint_clean() {
        // xtask sits at <repo>/rust/xtask
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().parent().unwrap();
        let outcome = run(root).expect("scan the repo");
        assert!(outcome.files_scanned > 40, "scanned {} files", outcome.files_scanned);
        let msgs: Vec<String> = outcome.violations.iter().map(|v| v.to_string()).collect();
        assert!(msgs.is_empty(), "violations on the real tree:\n{}", msgs.join("\n"));
    }
}
