//! Sampled-width µ-phase benchmark: phases 1+2 of Algorithm 1 (the
//! µ^t estimate — partial margins + derivative broadcast + gradient
//! slices) at b/c fractions {1.0, 0.25, 0.05}, masked full-width vs the
//! compact column-subset path, on dense and sparse presets.
//!
//! The `masked` rows run the pre-sampling execution: full-block-width
//! `w ∘ 1_B` payloads and full-width gradient slices, so their cost is
//! flat in the fraction. The `sampled` rows ship per-block sorted id
//! lists with compact payloads (`Cluster::partial_u_cols_into` /
//! `grad_cols_into`), so their cost scales with |B^t|/|C^t| — the
//! low-fraction speedup is this PR's acceptance criterion (≥ 3× at
//! b=c=0.05 on the dense preset, asserted below outside quick mode;
//! BENCH_5.json records the medians). Timed bodies include the
//! per-iteration prep each path actually pays (masking resp. boundary
//! splitting), over steady-state reused buffers.

use std::sync::Arc;

use sodda::cluster::Cluster;
use sodda::config::SamplingFractions;
use sodda::coordinator::sampling::{self, SampleSets};
use sodda::data::{synth, Grid};
use sodda::engine::NativeEngine;
use sodda::loss::Loss;
use sodda::util::arc_mut;
use sodda::util::bench::Bench;
use sodda::util::rng::Rng;

fn main() {
    let mut b = Bench::from_env("sampled");
    // 6 fat workers instead of the paper's 5x3: per-worker compute
    // dominates the channel round-trip on both dev boxes and 2-core
    // hosted runners, so the low-fraction ratio measures kernel width,
    // not mpsc latency
    let (n, m, p, q) = (6000usize, 2400usize, 3usize, 2usize);
    let mut dense_ratio_at_005 = None;
    for (label, ds) in
        [("dense", synth::dense_zhang(n, m, 1)), ("sparse", synth::sparse_pra(n, m, 48, 1))]
    {
        let grid = Grid::partition(&ds, p, q).unwrap();
        let layout = grid.layout.clone();
        let cluster = Cluster::launch(grid, Arc::new(NativeEngine), Loss::Hinge);
        let w: Vec<f32> = (0..m).map(|i| (i as f32 * 0.13).sin() * 0.4).collect();
        for frac in [1.0f64, 0.25, 0.05] {
            let mut rng = Rng::seed_from_u64(42);
            let fr = SamplingFractions { b: frac, c: frac, d: 0.85 };
            let sets = SampleSets::draw(&mut rng, n, m, &fr);
            let mut rows: Vec<Arc<Vec<u32>>> = (0..p).map(|_| Default::default()).collect();
            sampling::rows_per_partition_into(
                &sets.d,
                layout.row_bounds(),
                rows.iter_mut().map(arc_mut),
            );
            // steady-state buffers, reused across timed iterations
            let mut w_masked = Vec::new();
            let mut w_blocks: Vec<Arc<Vec<f32>>> = (0..q).map(|_| Default::default()).collect();
            let mut bcols: Vec<Arc<Vec<u32>>> = (0..q).map(|_| Default::default()).collect();
            let mut ccols: Vec<Arc<Vec<u32>>> = (0..q).map(|_| Default::default()).collect();
            let mut u = Vec::new();
            let mut g: Arc<Vec<f32>> = Arc::new(Vec::new());
            let inv_d = 1.0 / sets.d.len() as f32;

            let masked = b.bench(&format!("mu-phase/masked {label} b=c={frac:.2}"), || {
                sampling::mask_keep_into(&w, &sets.b, &mut w_masked);
                for (qi, wb) in w_blocks.iter_mut().enumerate() {
                    let dst = arc_mut(wb);
                    dst.clear();
                    dst.extend_from_slice(&w_masked[layout.block_cols(qi)]);
                }
                cluster.partial_u_into(&w_blocks, &rows, &NativeEngine, Loss::Hinge, &mut u);
                let gm = arc_mut(&mut g);
                cluster.grad_into(&u, &rows, gm);
                sampling::project_inplace(gm, &sets.c);
                for v in gm.iter_mut() {
                    *v *= inv_d;
                }
            });
            if frac == 1.0 {
                continue; // |B| = M: the sampled path falls back to masked
            }
            let sampled = b.bench(&format!("mu-phase/sampled {label} b=c={frac:.2}"), || {
                sampling::rows_per_partition_into(
                    &sets.b,
                    layout.col_bounds(),
                    bcols.iter_mut().map(arc_mut),
                );
                for (qi, wb) in w_blocks.iter_mut().enumerate() {
                    let base = layout.block_cols(qi).start;
                    let dst = arc_mut(wb);
                    dst.clear();
                    dst.extend(bcols[qi].iter().map(|&ci| w[base + ci as usize]));
                }
                cluster.partial_u_cols_into(
                    &w_blocks,
                    &bcols,
                    &rows,
                    &NativeEngine,
                    Loss::Hinge,
                    &mut u,
                );
                sampling::rows_per_partition_into(
                    &sets.c,
                    layout.col_bounds(),
                    ccols.iter_mut().map(arc_mut),
                );
                let gm = arc_mut(&mut g);
                cluster.grad_cols_into(&u, &ccols, &rows, gm);
                for &ci in sets.c.iter() {
                    gm[ci as usize] *= inv_d;
                }
            });
            if label == "dense" && frac == 0.05 {
                dense_ratio_at_005 = Some(masked.median_ns / sampled.median_ns);
            }
        }
    }
    let quick = b.quick;
    b.finish();
    // acceptance: ≥ 3× at b=c=0.05 on the dense preset. Quick mode
    // (CI smoke) only reports — its 200 ms budget is too noisy to gate
    // a ratio; full runs enforce it.
    if let Some(ratio) = dense_ratio_at_005 {
        println!("dense b=c=0.05 masked/sampled speedup: {ratio:.2}x");
        if !quick && ratio < 3.0 {
            eprintln!("REGRESSION: sampled-width speedup {ratio:.2}x < 3x at b=c=0.05");
            std::process::exit(1);
        }
    }
}
