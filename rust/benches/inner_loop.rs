//! The SVRG inner loop (Algorithm 1 steps 13-17) — the per-worker hot
//! path — across widths, storage formats, combiners and engines.

use sodda::data::synth;
use sodda::engine::{BlockKey, ComputeEngine, NativeEngine};
use sodda::loss::Loss;
use sodda::util::bench::Bench;
use sodda::util::rng::Rng;

fn main() {
    let mut b = Bench::from_env("inner_loop");
    let key = BlockKey { p: 0, q: 0 };
    let native = NativeEngine;
    let mut rng = Rng::seed_from_u64(3);

    for (mt, steps) in [(24usize, 32usize), (60, 32), (24, 128)] {
        let ds = synth::dense_zhang(1000, mt, 2);
        let w0: Vec<f32> = (0..mt).map(|i| (i as f32).cos() * 0.1).collect();
        let mu = vec![0.01f32; mt];
        let idx = rng.sample_with_replacement(1000, steps);
        // two-pass scalar reference: current + reference margins as
        // separate row-dots (the pre-fusion inner step)
        b.bench(&format!("scalar/two-pass/dense m̃={mt} L={steps}"), || {
            let mut w = w0.clone();
            for &j in &idx {
                let j = j as usize;
                let z_cur = ds.x.row_dot_range(j, 0, mt, &w);
                let z_ref = ds.x.row_dot_range(j, 0, mt, &w0);
                let du = Loss::Hinge.dloss(z_cur, ds.y[j]) - Loss::Hinge.dloss(z_ref, ds.y[j]);
                if du != 0.0 {
                    ds.x.add_row_scaled_range(j, 0, mt, -0.05 * du, &mut w);
                }
                for (wk, &mk) in w.iter_mut().zip(&mu) {
                    *wk -= 0.05 * mk;
                }
            }
            w
        });
        b.bench(&format!("native/dense m̃={mt} L={steps}"), || {
            native.svrg_inner(key, Loss::Hinge, &ds.x, &ds.y, 0..mt, &w0, &w0, &mu, &idx, 0.05)
        });
        b.bench(&format!("native/avg/dense m̃={mt} L={steps}"), || {
            native.svrg_inner_avg(key, Loss::Hinge, &ds.x, &ds.y, 0..mt, &w0, &w0, &mu, &idx, 0.05)
        });
    }

    let sp = synth::sparse_pra(1000, 24, 8, 4);
    let w0 = vec![0.05f32; 24];
    let mu = vec![0.01f32; 24];
    let idx = rng.sample_with_replacement(1000, 32);
    b.bench("native/sparse m̃=24 L=32", || {
        native.svrg_inner(key, Loss::Hinge, &sp.x, &sp.y, 0..24, &w0, &w0, &mu, &idx, 0.05)
    });

    #[cfg(feature = "xla")]
    match sodda::runtime::XlaRuntime::load("artifacts") {
        Ok(rt) => {
            let xla = sodda::engine::XlaEngine::new(std::sync::Arc::new(rt), 1000, 120, 24, 32)
                .expect("bucket");
            let ds = synth::dense_zhang(1000, 120, 2);
            let idx = Rng::seed_from_u64(5).sample_with_replacement(1000, 32);
            let w0 = vec![0.05f32; 24];
            let mu = vec![0.01f32; 24];
            let _ = xla.svrg_inner(key, Loss::Hinge, &ds.x, &ds.y, 0..24, &w0, &w0, &mu, &idx, 0.05);
            b.bench("xla/dense m̃=24 L=32", || {
                xla.svrg_inner(key, Loss::Hinge, &ds.x, &ds.y, 0..24, &w0, &w0, &mu, &idx, 0.05)
            });
        }
        Err(e) => eprintln!("(skipping xla rows: {e:#})"),
    }
    #[cfg(not(feature = "xla"))]
    eprintln!("(skipping xla rows: built without the `xla` feature)");

    b.finish();
}
