//! Straggler benchmark: balanced vs throughput-weighted row sharding
//! under heterogeneous worker profiles (PR 7's tentpole acceptance).
//!
//! A barrier phase waits for its slowest worker, so under a `one-slow`
//! profile the balanced layout's makespan is pinned to the straggler
//! while the weighted layout ([`ShardWeighting::Throughput`]) shrinks
//! the slow worker's row shard until every worker finishes the
//! row-proportional phases together. The headline ratio —
//! balanced/weighted simulated seconds per iteration — comes from the
//! `SimNet` cost model and is fully deterministic, so it is gated even
//! in quick mode (≥ 1.15× under `one-slow:4` on a 3×2 grid; the
//! analytic value is ≈ 2.8×: the µ/gradient phases improve 3× and the
//! row-count-independent inner loops don't move). Wall-clock rows are
//! report-only: the in-process executor runs workers back to back, so
//! host time measures total work, which weighting does not change.
//! BENCH_7.json records the ratios.

use sodda::config::{ClusterProfile, ExecutorKind, ShardWeighting};
use sodda::util::bench::Bench;
use sodda::{ExperimentConfig, Trainer};

const ITERS: usize = 8;

fn session(profile: ClusterProfile, weighting: ShardWeighting) -> ExperimentConfig {
    ExperimentConfig::builder()
        .name("straggler")
        .dense(6000, 600)
        .grid(3, 2)
        .inner_steps(4)
        .outer_iters(ITERS)
        .eval_every(ITERS)
        .fractions_bcd(1.0, 1.0, 0.85)
        .seed(42)
        .executor(ExecutorKind::InProcess)
        .cluster_profile(profile)
        .shard_weighting(weighting)
        .build()
        .unwrap()
}

/// Deterministic simulated seconds per outer iteration for one config.
fn sim_s_per_iter(cfg: ExperimentConfig) -> f64 {
    let mut t = Trainer::new(cfg).unwrap();
    t.run().unwrap();
    t.sim_seconds() / ITERS as f64
}

fn main() {
    let mut b = Bench::from_env("straggler");

    let mut gated_ratio = None;
    for (label, profile) in [
        ("one-slow:4", ClusterProfile::one_slow(4.0)),
        ("long-tail:4", ClusterProfile::long_tail(4.0)),
    ] {
        let balanced = sim_s_per_iter(session(profile.clone(), ShardWeighting::Balanced));
        let weighted = sim_s_per_iter(session(profile, ShardWeighting::Throughput));
        let ratio = balanced / weighted;
        println!(
            "{label}: balanced {:.3} ms/iter (sim), weighted {:.3} ms/iter (sim), ratio {ratio:.2}x",
            balanced * 1e3,
            weighted * 1e3
        );
        if label == "one-slow:4" {
            gated_ratio = Some(ratio);
        }
    }
    // sanity row: uniform profiles must not regress under weighting
    // (Throughput falls back to the balanced boundary vectors)
    let base = sim_s_per_iter(session(ClusterProfile::uniform(), ShardWeighting::Balanced));
    let thru = sim_s_per_iter(session(ClusterProfile::uniform(), ShardWeighting::Throughput));
    println!("uniform: balanced {:.3} ms/iter (sim), weighted identical: {}", base * 1e3, base == thru);

    // wall-clock presence rows for the bench-gate file (report-only
    // medians; the gated quantity above is simulated, not measured)
    for (name, weighting) in [
        ("one outer iter balanced (one-slow:4 3x2)", ShardWeighting::Balanced),
        ("one outer iter weighted (one-slow:4 3x2)", ShardWeighting::Throughput),
    ] {
        let mut t =
            Trainer::new(session(ClusterProfile::one_slow(4.0), weighting)).unwrap();
        b.bench(name, || {
            if t.is_done() {
                t.reset();
            }
            t.step().unwrap();
        });
    }
    b.finish();

    // the model ratio is deterministic — gate it in every mode
    if let Some(ratio) = gated_ratio {
        if ratio < 1.15 {
            eprintln!(
                "REGRESSION: weighted sharding beats balanced by only {ratio:.2}x \
                 (< 1.15x) under one-slow:4"
            );
            std::process::exit(1);
        }
        if base != thru {
            eprintln!("REGRESSION: Throughput weighting changed the uniform-profile cost model");
            std::process::exit(1);
        }
    }
}
