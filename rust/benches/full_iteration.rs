//! End-to-end outer iterations: wall-clock per iteration for each
//! algorithm on the `small` preset at laptop scale (the meso-benchmark
//! behind the Figure 2/3 time axes). Also contrasts the per-run session
//! staging cost (legacy shim) against a reused `Trainer` session.
//!
//! The binary installs the counting allocator, so every row carries
//! `allocs_per_iter` in the JSON report; the steady-state row's count is
//! gated absolutely by `benches/baseline.json` (`max_allocs_per_iter`) —
//! the pooled-buffer regression tripwire.

use std::sync::Arc;

use sodda::config::{preset, AlgorithmKind, ExperimentConfig, SamplingFractions};
use sodda::coordinator::train_with_engine;
use sodda::engine::NativeEngine;
use sodda::util::alloc::CountingAlloc;
use sodda::util::bench::Bench;
use sodda::Trainer;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn alloc_events() -> u64 {
    ALLOC.allocations()
}

fn main() {
    let mut b = Bench::from_env("full_iteration");
    b.set_alloc_counter(alloc_events);
    let pr = preset("small").unwrap();
    let dc = pr.data_config(pr.default_scale, 5, 3);
    let ds = dc.try_materialize(1).expect("materializing small preset");

    let base = ExperimentConfig::builder()
        .name("bench_base")
        .data(dc)
        .grid(5, 3)
        .outer_iters(2)
        .eval_every(2) // keep objective eval out of the measured loop
        .build()
        .expect("bench config");

    for algo in [AlgorithmKind::Sodda, AlgorithmKind::Radisa, AlgorithmKind::RadisaAvg] {
        let cfg = base
            .to_builder()
            .name(format!("bench_{algo}"))
            .algorithm(algo)
            .fractions(if algo == AlgorithmKind::Sodda {
                SamplingFractions::PAPER
            } else {
                SamplingFractions::FULL
            })
            .build()
            .expect("bench config");
        b.bench(&format!("{algo}/2 iters (small preset)"), || {
            train_with_engine(&cfg, &ds, Arc::new(NativeEngine)).unwrap()
        });
    }

    // the session API amortizes staging: reconfigure + run vs full re-stage
    let mut session =
        Trainer::with_parts(base.clone(), ds.clone(), Arc::new(NativeEngine)).expect("session");
    b.bench("sodda/2 iters (reused session)", || {
        session.reset();
        session.run().unwrap()
    });

    // steady state proper: one outer iteration (eval included) on a warm
    // session — the allocs_per_iter of this row is the pooled-buffer
    // budget gated by benches/baseline.json
    let steady_cfg = base
        .to_builder()
        .name("bench_steady")
        .outer_iters(1_000_000)
        .eval_every(1)
        .build()
        .expect("bench config");
    let mut steady =
        Trainer::with_parts(steady_cfg, ds.clone(), Arc::new(NativeEngine)).expect("session");
    for _ in 0..3 {
        steady.step().unwrap(); // warm the pools before measurement
    }
    b.bench("sodda/1 outer iter (steady state)", || {
        if steady.is_done() {
            steady.reset();
        }
        steady.step().unwrap()
    });

    b.finish();
}
