//! End-to-end outer iterations: wall-clock per iteration for each
//! algorithm on the `small` preset at laptop scale (the meso-benchmark
//! behind the Figure 2/3 time axes).

use std::sync::Arc;

use sodda::config::{preset, AlgorithmKind, ExperimentConfig, SamplingFractions, Schedule};
use sodda::coordinator::train_with_engine;
use sodda::engine::NativeEngine;
use sodda::loss::Loss;
use sodda::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("full_iteration");
    let pr = preset("small").unwrap();
    let dc = pr.data_config(pr.default_scale, 5, 3);
    let ds = dc.materialize(1);

    for algo in [AlgorithmKind::Sodda, AlgorithmKind::Radisa, AlgorithmKind::RadisaAvg] {
        let cfg = ExperimentConfig {
            name: format!("bench_{algo}"),
            data: dc.clone(),
            p: 5,
            q: 3,
            loss: Loss::Hinge,
            algorithm: algo,
            fractions: if algo == AlgorithmKind::Sodda {
                SamplingFractions::PAPER
            } else {
                SamplingFractions::FULL
            },
            inner_steps: 32,
            outer_iters: 2,
            schedule: Schedule::ScaledSqrt { gamma0: 0.08 },
            seed: 1,
            engine: Default::default(),
            network: None,
            eval_every: 2, // keep objective eval out of the measured loop
        };
        b.bench(&format!("{algo}/2 iters (small preset)"), || {
            train_with_engine(&cfg, &ds, Arc::new(NativeEngine)).unwrap()
        });
    }

    b.finish();
}
