//! End-to-end outer iterations: wall-clock per iteration for each
//! algorithm on the `small` preset at laptop scale (the meso-benchmark
//! behind the Figure 2/3 time axes). Also contrasts the per-run session
//! staging cost (legacy shim) against a reused `Trainer` session.
//!
//! The binary installs the counting allocator, so every row carries
//! `allocs_per_iter` in the JSON report; the steady-state row's count is
//! gated absolutely by `benches/baseline.json` (`max_allocs_per_iter`) —
//! the pooled-buffer regression tripwire.
//!
//! The executor-comparison section runs the same warm session once per
//! executor (in-process oracle vs thread-per-worker) on a 3×2 grid,
//! annotates each row with `wall_ns_per_iter` and the SimNet
//! `sim_ns_per_iter`, and — outside `BENCH_QUICK`, on ≥ 4 cores —
//! fails the binary unless the threaded mode shows a ≥ 1.2× wall-clock
//! speedup (ISSUE 6's acceptance gate, recorded in BENCH_6.json).

use std::sync::Arc;

use sodda::config::{preset, AlgorithmKind, ExecutorKind, ExperimentConfig, SamplingFractions};
use sodda::coordinator::train_with_engine;
use sodda::engine::NativeEngine;
use sodda::util::alloc::CountingAlloc;
use sodda::util::bench::Bench;
use sodda::Trainer;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn alloc_events() -> u64 {
    ALLOC.allocations()
}

fn main() {
    // the rows here compare executors explicitly (config pins); the
    // lane-wide env knob must not skew the pinned-default rows below
    sodda::util::env::unset(ExecutorKind::ENV);
    let mut b = Bench::from_env("full_iteration");
    b.set_alloc_counter(alloc_events);
    let pr = preset("small").unwrap();
    let dc = pr.data_config(pr.default_scale, 5, 3);
    let ds = dc.try_materialize(1).expect("materializing small preset");

    let base = ExperimentConfig::builder()
        .name("bench_base")
        .data(dc)
        .grid(5, 3)
        .outer_iters(2)
        .eval_every(2) // keep objective eval out of the measured loop
        .build()
        .expect("bench config");

    for algo in [AlgorithmKind::Sodda, AlgorithmKind::Radisa, AlgorithmKind::RadisaAvg] {
        let cfg = base
            .to_builder()
            .name(format!("bench_{algo}"))
            .algorithm(algo)
            .fractions(if algo == AlgorithmKind::Sodda {
                SamplingFractions::PAPER
            } else {
                SamplingFractions::FULL
            })
            .build()
            .expect("bench config");
        b.bench(&format!("{algo}/2 iters (small preset)"), || {
            train_with_engine(&cfg, &ds, Arc::new(NativeEngine)).unwrap()
        });
    }

    // the session API amortizes staging: reconfigure + run vs full re-stage
    let mut session =
        Trainer::with_parts(base.clone(), ds.clone(), Arc::new(NativeEngine)).expect("session");
    b.bench("sodda/2 iters (reused session)", || {
        session.reset();
        session.run().unwrap()
    });

    // steady state proper: one outer iteration (eval included) on a warm
    // session — the allocs_per_iter of this row is the pooled-buffer
    // budget gated by benches/baseline.json
    let steady_cfg = base
        .to_builder()
        .name("bench_steady")
        .outer_iters(1_000_000)
        .eval_every(1)
        .build()
        .expect("bench config");
    let mut steady =
        Trainer::with_parts(steady_cfg, ds.clone(), Arc::new(NativeEngine)).expect("session");
    for _ in 0..3 {
        steady.step().unwrap(); // warm the pools before measurement
    }
    b.bench("sodda/1 outer iter (steady state)", || {
        if steady.is_done() {
            steady.reset();
        }
        steady.step().unwrap()
    });

    // ---- executor comparison: oracle vs real threads on a 3x2 grid ----
    // One shared dataset, one warm session per executor, objective eval
    // off the measured path (eval_every = outer_iters; the iteration-0
    // record is evaluated once during warmup). Blocks are large enough
    // (~1330x960) that per-worker compute dominates mailbox overhead.
    let exec_dc = sodda::config::DataConfig::Dense { n: 4000, m: 1920 };
    let exec_ds = Arc::new(exec_dc.try_materialize(7).expect("materializing executor bench data"));
    let mut medians = Vec::new();
    for kind in [ExecutorKind::InProcess, ExecutorKind::Threaded] {
        let cfg = ExperimentConfig::builder()
            .name(format!("bench_exec_{kind}"))
            .data(exec_dc.clone())
            .grid(3, 2)
            .inner_steps(32)
            .outer_iters(1_000_000)
            .eval_every(1_000_000)
            .seed(7)
            .executor(kind)
            .build()
            .expect("bench config");
        let mut s = Trainer::with_parts(cfg, Arc::clone(&exec_ds), Arc::new(NativeEngine))
            .expect("session");
        for _ in 0..2 {
            s.step().unwrap(); // warm pools + per-worker scratch
        }
        // SimNet charge for one steady-state iteration (identical across
        // executors — the cost model sees the protocol, not the substrate)
        let sim0 = s.sim_seconds();
        s.step().unwrap();
        let sim_ns_per_iter = (s.sim_seconds() - sim0) * 1e9;
        let stats = b.bench(&format!("sodda/1 outer iter ({kind} 3x2)"), || {
            if s.is_done() {
                s.reset();
            }
            s.step().unwrap()
        });
        b.annotate("wall_ns_per_iter", stats.median_ns);
        b.annotate("sim_ns_per_iter", sim_ns_per_iter);
        medians.push(stats.median_ns);
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let speedup = medians[0] / medians[1];
    println!("executor speedup (in-process / threaded medians): {speedup:.2}x on {cores} cores");
    if !b.quick && cores >= 4 {
        // the acceptance gate: real threads must beat the sequential
        // oracle by 1.2x wall-clock on a 3x2 grid when cores are there
        if speedup < 1.2 {
            eprintln!(
                "FAIL: threaded executor speedup {speedup:.2}x < 1.2x on {cores} cores \
                 (in-process {:.0} ns/iter vs threaded {:.0} ns/iter)",
                medians[0],
                medians[1]
            );
            b.finish();
            std::process::exit(1);
        }
    } else {
        println!(
            "(speedup gate skipped: quick={} cores={cores} — needs !quick and >= 4 cores)",
            b.quick
        );
    }

    b.finish();
}
