//! One bench row per paper table/figure: times the harness that
//! regenerates each artifact (shortened iteration counts; the full
//! regeneration is `make figures`). Always uses tmp output dirs.

use sodda::config::EngineKind;
use sodda::harness::{self, Opts};
use sodda::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("paper_tables");
    let base = Opts {
        out_dir: std::env::temp_dir().join("sodda-bench-results"),
        scale: 400, // small data: this measures harness overhead + shape
        iters: 4,
        engine: EngineKind::Native,
        p: 5,
        q: 3,
        inner_steps: 16,
        gamma0: 0.08,
        seed: 1,
    };

    b.bench("table1", || harness::table1(&base).unwrap());
    b.bench("table3", || harness::table3(&base).unwrap());
    b.bench("fig2/panel-a", || harness::fig2(&base, 'a').unwrap());
    b.bench("fig2/panel-c", || harness::fig2(&base, 'c').unwrap());
    b.bench("fig3", || harness::fig3(&base).unwrap());
    b.bench("fig4", || harness::fig4(&base).unwrap());
    let mut t2 = base.clone();
    t2.iters = 3;
    b.bench("table2", || harness::table2(&t2).unwrap());

    b.finish();
}
