//! Micro-benchmarks of the L1-equivalent compute primitives: the batched
//! native kernel layer vs the per-row scalar path it replaced, and (with
//! `--features xla` + `make artifacts`) the AOT JAX/Pallas artifacts
//! through PJRT.
//!
//! `BENCH_QUICK=1` shortens measurement for CI smoke; `BENCH_OUT`
//! overrides the JSON report path (default `target/bench/kernels.json`).
//! The `scalar/...` rows drive the same per-row `Store` ops the
//! pre-batching `NativeEngine` used, so the `native/...` rows quantify
//! exactly what batching + fusion buys (see BENCH_2.json).

use sodda::data::synth;
use sodda::engine::{BlockKey, ComputeEngine, NativeEngine};
use sodda::loss::Loss;
use sodda::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("kernels");
    let key = BlockKey { p: 0, q: 0 };

    // shapes matching the default artifact bucket (n=1000, m=120)
    let dense = synth::dense_zhang(1000, 120, 1);
    let sparse = synth::sparse_pra(1000, 120, 12, 1);
    let w: Vec<f32> = (0..120).map(|i| (i as f32 * 0.1).sin()).collect();
    let rows: Vec<u32> = (0..1000).collect();
    let u: Vec<f32> = (0..1000).map(|i| ((i % 3) as f32 - 1.0) * 0.5).collect();
    let native = NativeEngine;
    let dense_elems = 1000 * 120u64;
    let sparse_elems = sparse.x.nnz() as u64;

    // ---- partial_z: per-row scalar reference vs batched kernel --------------
    b.bench_elems("scalar/partial_z/dense 1000x120", dense_elems, || {
        rows.iter().map(|&r| dense.x.row_dot_range(r as usize, 0, 120, &w)).collect::<Vec<f32>>()
    });
    b.bench_elems("native/partial_z/dense 1000x120", dense_elems, || {
        native.partial_z(key, &dense.x, 0..120, &w, &rows)
    });
    b.bench_elems("scalar/partial_z/sparse 1000x120", sparse_elems, || {
        rows.iter().map(|&r| sparse.x.row_dot_range(r as usize, 0, 120, &w)).collect::<Vec<f32>>()
    });
    b.bench_elems("native/partial_z/sparse 1000x120", sparse_elems, || {
        native.partial_z(key, &sparse.x, 0..120, &w, &rows)
    });

    // ---- grad_slice ---------------------------------------------------------
    b.bench_elems("scalar/grad_slice/dense 1000x120", dense_elems, || {
        let mut g = vec![0.0f32; 120];
        for (&r, &uk) in rows.iter().zip(&u) {
            dense.x.add_row_scaled_range(r as usize, 0, 120, uk, &mut g);
        }
        g
    });
    b.bench_elems("native/grad_slice/dense 1000x120", dense_elems, || {
        native.grad_slice(key, &dense.x, 0..120, &rows, &u)
    });
    b.bench_elems("scalar/grad_slice/sparse 1000x120", sparse_elems, || {
        let mut g = vec![0.0f32; 120];
        for (&r, &uk) in rows.iter().zip(&u) {
            sparse.x.add_row_scaled_range(r as usize, 0, 120, uk, &mut g);
        }
        g
    });
    b.bench_elems("native/grad_slice/sparse 1000x120", sparse_elems, || {
        native.grad_slice(key, &sparse.x, 0..120, &rows, &u)
    });

    // ---- fused partial_u vs compose (z, gather y, dloss) --------------------
    b.bench_elems("scalar/partial_u/dense 1000x120", dense_elems, || {
        let z: Vec<f32> =
            rows.iter().map(|&r| dense.x.row_dot_range(r as usize, 0, 120, &w)).collect();
        let y_rows: Vec<f32> = rows.iter().map(|&r| dense.y[r as usize]).collect();
        native.dloss_u(Loss::Hinge, &z, &y_rows)
    });
    b.bench_elems("native/partial_u/dense 1000x120", dense_elems, || {
        native.partial_u(key, Loss::Hinge, &dense.x, 0..120, &w, &rows, &dense.y)
    });

    // ---- elementwise + objective --------------------------------------------
    let z = native.partial_z(key, &dense.x, 0..120, &w, &rows);
    b.bench("native/dloss_u/hinge 1000", || native.dloss_u(Loss::Hinge, &z, &dense.y));
    b.bench("native/loss_from_z/hinge 1000", || native.loss_from_z(Loss::Hinge, &z, &dense.y));
    b.bench_elems("native/block_loss/dense 1000x120", dense_elems, || {
        native.block_loss(key, Loss::Hinge, &dense.x, 0..120, &w, &rows, &dense.y)
    });

    // XLA path (needs the default artifact bucket and --features xla)
    #[cfg(feature = "xla")]
    match sodda::runtime::XlaRuntime::load("artifacts") {
        Ok(rt) => {
            let xla = sodda::engine::XlaEngine::new(std::sync::Arc::new(rt), 1000, 120, 24, 32)
                .expect("bucket matches");
            // first calls compile + stage; do them outside timing
            let _ = xla.partial_z(key, &dense.x, 0..120, &w, &rows);
            let _ = xla.grad_slice(key, &dense.x, 0..120, &rows, &u);
            let _ = xla.dloss_u(Loss::Hinge, &z, &dense.y);
            b.bench_elems("xla/partial_z/dense 1000x120", dense_elems, || {
                xla.partial_z(key, &dense.x, 0..120, &w, &rows)
            });
            b.bench_elems("xla/grad_slice/dense 1000x120", dense_elems, || {
                xla.grad_slice(key, &dense.x, 0..120, &rows, &u)
            });
            b.bench("xla/dloss_u/hinge 1000", || xla.dloss_u(Loss::Hinge, &z, &dense.y));
            b.bench("xla/loss_from_z/hinge 1000", || xla.loss_from_z(Loss::Hinge, &z, &dense.y));
        }
        Err(e) => eprintln!("(skipping xla rows: {e:#})"),
    }
    #[cfg(not(feature = "xla"))]
    eprintln!("(skipping xla rows: built without the `xla` feature)");

    b.finish();
}
