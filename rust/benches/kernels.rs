//! Micro-benchmarks of the L1-equivalent compute primitives: native rust
//! vs the AOT JAX/Pallas artifacts through PJRT.
//!
//! Run `make artifacts` first for the XLA rows (they skip otherwise).
//! BENCH_QUICK=1 shortens measurement for CI smoke.

use sodda::data::synth;
use sodda::engine::{BlockKey, ComputeEngine, NativeEngine};
use sodda::loss::Loss;
use sodda::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("kernels");
    let key = BlockKey { p: 0, q: 0 };

    // shapes matching the default artifact bucket (n=1000, m=120)
    let dense = synth::dense_zhang(1000, 120, 1);
    let sparse = synth::sparse_pra(1000, 120, 12, 1);
    let w: Vec<f32> = (0..120).map(|i| (i as f32 * 0.1).sin()).collect();
    let rows: Vec<u32> = (0..1000).collect();
    let u: Vec<f32> = (0..1000).map(|i| ((i % 3) as f32 - 1.0) * 0.5).collect();
    let native = NativeEngine;

    b.bench("native/partial_z/dense 1000x120", || {
        native.partial_z(key, &dense.x, 0..120, &w, &rows)
    });
    b.bench("native/partial_z/sparse 1000x120", || {
        native.partial_z(key, &sparse.x, 0..120, &w, &rows)
    });
    b.bench("native/grad_slice/dense 1000x120", || {
        native.grad_slice(key, &dense.x, 0..120, &rows, &u)
    });
    b.bench("native/grad_slice/sparse 1000x120", || {
        native.grad_slice(key, &sparse.x, 0..120, &rows, &u)
    });
    let z = native.partial_z(key, &dense.x, 0..120, &w, &rows);
    b.bench("native/dloss_u/hinge 1000", || native.dloss_u(Loss::Hinge, &z, &dense.y));
    b.bench("native/loss_from_z/hinge 1000", || native.loss_from_z(Loss::Hinge, &z, &dense.y));

    // XLA path (needs the default artifact bucket and --features xla)
    #[cfg(feature = "xla")]
    match sodda::runtime::XlaRuntime::load("artifacts") {
        Ok(rt) => {
            let xla = sodda::engine::XlaEngine::new(std::sync::Arc::new(rt), 1000, 120, 24, 32)
                .expect("bucket matches");
            // first calls compile + stage; do them outside timing
            let _ = xla.partial_z(key, &dense.x, 0..120, &w, &rows);
            let _ = xla.grad_slice(key, &dense.x, 0..120, &rows, &u);
            let _ = xla.dloss_u(Loss::Hinge, &z, &dense.y);
            b.bench("xla/partial_z/dense 1000x120", || {
                xla.partial_z(key, &dense.x, 0..120, &w, &rows)
            });
            b.bench("xla/grad_slice/dense 1000x120", || {
                xla.grad_slice(key, &dense.x, 0..120, &rows, &u)
            });
            b.bench("xla/dloss_u/hinge 1000", || xla.dloss_u(Loss::Hinge, &z, &dense.y));
            b.bench("xla/loss_from_z/hinge 1000", || xla.loss_from_z(Loss::Hinge, &z, &dense.y));
        }
        Err(e) => eprintln!("(skipping xla rows: {e:#})"),
    }
    #[cfg(not(feature = "xla"))]
    eprintln!("(skipping xla rows: built without the `xla` feature)");

    b.finish();
}
