//! Bounded-staleness benchmark: hard barrier vs 0.75 quorum under a
//! persistent modeled straggler (PR 10's tentpole acceptance).
//!
//! Under `one-slow:4` a barrier phase is pinned to the 4×-slow worker,
//! while a `0.75` quorum on the 3×2 grid releases at the 5th of six
//! block replies — the straggler's reply parks in the `LateSet` and
//! folds into the next iteration at half weight. Both headline numbers
//! come from the `SimNet` cost model and are fully deterministic, so
//! they are gated even in quick mode:
//!
//! - simulated seconds per outer iteration must improve by ≥ 1.3×
//!   (the µ/gradient phases improve ~4×; the straggler's inner loops
//!   still bound phase 3, which caps the overall ratio well below 4);
//! - statistical efficiency must survive the stale folds: at the
//!   quorum run's final simulated time, its loss must be ≤ 1.05× the
//!   barrier's loss at the same simulated budget (the barrier has
//!   completed ~3× fewer iterations by then, so this holds with slack
//!   unless late folding actively corrupts the aggregates).
//!
//! Wall-clock rows are report-only, as in `benches/straggler.rs`: the
//! in-process executor runs workers back to back, so host time measures
//! total work, which quorum release does not change. BENCH_10.json
//! records the ratios.

use sodda::config::{ClusterProfile, ExecutorKind};
use sodda::util::bench::Bench;
use sodda::{ExperimentConfig, StalenessPolicy, Trainer, TrainOutcome};

const ITERS: usize = 8;

fn session(staleness: Option<StalenessPolicy>) -> ExperimentConfig {
    let mut b = ExperimentConfig::builder()
        .name("staleness")
        .dense(6000, 600)
        .grid(3, 2)
        .inner_steps(4)
        .outer_iters(ITERS)
        .eval_every(1)
        .fractions_bcd(1.0, 1.0, 0.85)
        .seed(42)
        .executor(ExecutorKind::InProcess)
        .cluster_profile(ClusterProfile::one_slow(4.0));
    if let Some(pol) = staleness {
        b = b.staleness(pol);
    }
    b.build().unwrap()
}

fn run(cfg: ExperimentConfig) -> TrainOutcome {
    Trainer::new(cfg).unwrap().run().unwrap()
}

fn quorum() -> StalenessPolicy {
    StalenessPolicy { quorum_frac: 0.75, max_staleness_iters: 2, timeout_factor: 4.0 }
}

fn main() {
    let mut b = Bench::from_env("staleness");

    let barrier = run(session(None));
    let bounded = run(session(Some(quorum())));

    let end = |o: &TrainOutcome| *o.history.records.last().unwrap();
    let (b_end, q_end) = (end(&barrier), end(&bounded));
    let speedup = b_end.sim_s / q_end.sim_s;
    println!(
        "one-slow:4 3x2: barrier {:.3} ms/iter (sim), quorum@0.75 {:.3} ms/iter (sim), \
         speedup {speedup:.2}x",
        b_end.sim_s / ITERS as f64 * 1e3,
        q_end.sim_s / ITERS as f64 * 1e3
    );

    // loss at equal simulated budget: the barrier record closest below
    // the quorum run's final simulated time
    let b_at = barrier
        .history
        .records
        .iter()
        .filter(|r| r.sim_s <= q_end.sim_s)
        .last()
        .unwrap_or(&barrier.history.records[0]);
    let loss_ratio = q_end.loss / b_at.loss;
    println!(
        "loss at sim budget {:.3} ms: quorum {:.6} vs barrier {:.6} (iter {}), \
         ratio {loss_ratio:.3}",
        q_end.sim_s * 1e3,
        q_end.loss,
        b_at.loss,
        b_at.iter
    );
    let parked: usize = bounded.history.staleness.iter().map(|r| r.late).sum();
    let folds: usize = bounded.history.staleness.iter().map(|r| r.folds).sum();
    println!("staleness log: {parked} parked, {folds} folded over {ITERS} iters");

    // wall-clock presence rows for the bench-gate file (report-only
    // medians; the gated quantities above are simulated, not measured)
    for (name, policy) in [
        ("one outer iter barrier (one-slow:4 3x2)", None),
        ("one outer iter quorum@0.75 (one-slow:4 3x2)", Some(quorum())),
    ] {
        let mut t = Trainer::new(session(policy)).unwrap();
        b.bench(name, || {
            if t.is_done() {
                t.reset();
            }
            t.step().unwrap();
        });
    }
    b.finish();

    // the model ratios are deterministic — gate them in every mode
    if speedup < 1.3 {
        eprintln!(
            "REGRESSION: quorum release beats the barrier by only {speedup:.2}x \
             (< 1.3x) under one-slow:4"
        );
        std::process::exit(1);
    }
    if loss_ratio > 1.05 {
        eprintln!(
            "REGRESSION: bounded staleness costs {loss_ratio:.3}x loss (> 1.05x) \
             at an equal simulated budget"
        );
        std::process::exit(1);
    }
    if parked == 0 || folds == 0 {
        eprintln!("REGRESSION: the straggler was never parked/folded — the gate proved nothing");
        std::process::exit(1);
    }
}
