//! Re-shard benchmark: the cost of elastic degradation (PR 9's
//! tentpole acceptance).
//!
//! A permanent worker loss triggers a live re-shard: the trainer rolls
//! the interrupted iteration back, re-partitions the surviving data
//! onto a grid one row-partition smaller, and charges the `SimNet` for
//! the shuffle. Both halves of that charge are deterministic model
//! outputs, so they are **gated on every run, quick mode included**:
//!
//! * the shuffle bytes must equal an independent re-partition's summed
//!   wire size (`Store::approx_bytes` + labels) — the accounting is
//!   honest, not an estimate;
//! * the shuffle must cost simulated time (> 0), and the degraded run
//!   must still complete its full horizon on the shrunk grid.
//!
//! Wall-clock rows are report-only medians for the bench-gate file:
//! they time a short degraded run (kill → rollback → re-shard →
//! continue) next to its clean twin, on the in-process executor.

use sodda::config::ExecutorKind;
use sodda::data::{Grid, Layout};
use sodda::util::bench::Bench;
use sodda::{ExperimentConfig, Trainer};

const ITERS: usize = 6;

fn session(n: usize, m: usize, iters: usize) -> ExperimentConfig {
    ExperimentConfig::builder()
        .name("reshard")
        .dense(n, m)
        .grid(3, 2)
        .inner_steps(4)
        .outer_iters(iters)
        .eval_every(iters)
        .fractions_bcd(1.0, 1.0, 0.85)
        .seed(42)
        .executor(ExecutorKind::InProcess)
        .build()
        .unwrap()
}

fn main() {
    let mut b = Bench::from_env("reshard");

    // ---- deterministic gates: honest shuffle accounting ----------------
    let (n, m) = (6000, 600);
    let mut t = Trainer::new(session(n, m, ITERS)).unwrap();
    t.set_fault_plan(Some("1@3:grad!perm".parse().unwrap()));
    t.run().unwrap();
    let reshards = t.history().reshards.clone();
    assert_eq!(reshards.len(), 1, "expected exactly one re-shard");
    let r = reshards[0];
    println!(
        "perm loss of worker {} at iter {}: {}x{} -> {}x{}, shuffled {} bytes in {:.3} sim ms",
        r.worker,
        r.iter,
        r.from_p,
        r.from_q,
        r.to_p,
        r.to_q,
        r.bytes,
        r.sim_s * 1e3
    );

    // independently re-partition the dataset at the shrunk shape and sum
    // the wire size of every block the re-shard had to move
    let layout = Layout::new(n, m, r.to_p, r.to_q).unwrap();
    let grid = Grid::partition_with_layout(t.dataset(), layout).unwrap();
    let expected: u64 =
        grid.blocks().map(|blk| (blk.x.approx_bytes() + 4 * blk.y.len()) as u64).sum();

    let mut failed = false;
    if r.bytes != expected {
        eprintln!(
            "REGRESSION: re-shard charged {} bytes but the shrunk partition weighs {} — \
             the SimNet shuffle accounting is dishonest",
            r.bytes, expected
        );
        failed = true;
    }
    if r.sim_s <= 0.0 {
        eprintln!("REGRESSION: the re-shard shuffle cost no simulated time");
        failed = true;
    }
    if !t.is_done() || t.history().records.last().map(|rec| rec.iter) != Some(ITERS) {
        eprintln!("REGRESSION: the degraded run did not complete its horizon");
        failed = true;
    }
    if (t.config().p, t.config().q) != (r.to_p, r.to_q) {
        eprintln!("REGRESSION: the session is not running on the shrunk grid it logged");
        failed = true;
    }

    // ---- report-only wall rows (smaller shape: each sample stages,
    // kills, re-shards and finishes a whole run) ------------------------
    b.bench("degraded run (perm@2, 3x2 -> 2x2)", || {
        let mut t = Trainer::new(session(1200, 240, 4)).unwrap();
        t.set_fault_plan(Some("1@2:grad!perm".parse().unwrap()));
        t.run().unwrap()
    });
    b.bench("clean run (same shape, 3x2)", || {
        let mut t = Trainer::new(session(1200, 240, 4)).unwrap();
        t.set_fault_plan(None);
        t.run().unwrap()
    });
    b.finish();

    if failed {
        std::process::exit(1);
    }
}
