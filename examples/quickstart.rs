//! Quickstart: train a hinge-loss SVM with SODDA on a doubly distributed
//! synthetic dataset and print the loss curve.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the native engine so it runs without `make artifacts`; pass
//! `--engine xla` (after `make artifacts`) to execute the AOT JAX/Pallas
//! kernels through PJRT instead.

use std::sync::Arc;

use sodda::config::{AlgorithmKind, DataConfig, EngineKind, ExperimentConfig, SamplingFractions, Schedule};
use sodda::coordinator::{build_engine, train_with_engine};
use sodda::loss::Loss;
use sodda::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let engine_kind: EngineKind =
        args.str_or("engine", "native").parse().map_err(anyhow::Error::msg)?;

    // The paper's default partitioning: P = 5 observation partitions,
    // Q = 3 feature partitions; (b, c, d) = (85%, 80%, 85%).
    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        data: DataConfig::Dense { n: 5000, m: 360 },
        p: 5,
        q: 3,
        loss: Loss::Hinge,
        algorithm: AlgorithmKind::Sodda,
        fractions: SamplingFractions::PAPER,
        inner_steps: 32,
        outer_iters: 25,
        schedule: Schedule::ScaledSqrt { gamma0: 0.08 },
        seed: 42,
        engine: engine_kind,
        network: None,
        eval_every: 1,
    };
    cfg.validate()?;

    let ds = cfg.data.materialize(cfg.seed);
    println!("dataset: {} ({} observations × {} features)", ds.name, ds.n(), ds.m());
    let engine = build_engine(&cfg)?;
    println!("engine:  {}\n", engine.name());

    let out = train_with_engine(&cfg, &ds, Arc::clone(&engine))?;
    println!("iter   F(w)      sim_s");
    for r in &out.history.records {
        println!("{:4}   {:.5}   {:.4}", r.iter, r.loss, r.sim_s);
    }
    println!(
        "\nF(0) = {:.4} → F(w_T) = {:.4}; {:.2} MB simulated communication",
        out.history.losses()[0],
        out.history.final_loss().unwrap(),
        out.comm_bytes as f64 / 1e6
    );
    Ok(())
}
