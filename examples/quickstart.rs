//! Quickstart: train a hinge-loss SVM with SODDA on a doubly distributed
//! synthetic dataset, streaming the loss curve through an observer.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the native engine so it runs without `make artifacts`; pass
//! `--engine xla` (after `make artifacts` and building with
//! `--features xla`) to execute the AOT JAX/Pallas kernels through PJRT.
//! `--threads` (or `--executor threaded`) runs the P×Q grid on real
//! worker threads instead of the sequential in-process oracle — same
//! bits, real parallelism (README "Execution modes").
//! `--n/--m/--iters` shrink the run — CI's example-smoke job drives
//! `--n 600 --m 60 --iters 3` (even grid) and `--n 601 --m 61 --iters 3`
//! (ragged grid) to exercise the session API end-to-end on every PR.

use std::ops::ControlFlow;

use sodda::config::{EngineKind, ExecutorKind};
use sodda::util::cli::Args;
use sodda::{ExperimentConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let engine_kind: EngineKind =
        args.str_or("engine", "native").parse().map_err(anyhow::Error::msg)?;

    // The paper's default partitioning: P = 5 observation partitions,
    // Q = 3 feature partitions; (b, c, d) = (85%, 80%, 85%) — the
    // builder's defaults. Validation (fraction ranges, schedule sanity)
    // happens at build time; any N × M works — shapes that don't divide
    // evenly into the grid get balanced ragged partitions.
    let mut builder = ExperimentConfig::builder()
        .name("quickstart")
        .dense(args.parse_or("n", 5000usize)?, args.parse_or("m", 360usize)?)
        .grid(5, 3)
        .outer_iters(args.parse_or("iters", 25usize)?)
        .seed(42)
        .engine(engine_kind);
    // --threads / --executor pin the runtime; otherwise SODDA_EXECUTOR
    // decides, defaulting to the deterministic in-process oracle
    if args.has("threads") {
        builder = builder.executor(ExecutorKind::Threaded);
    }
    if let Some(e) = args.get("executor") {
        builder = builder.executor(e.parse().map_err(anyhow::Error::msg)?);
    }
    let cfg = builder.build()?;

    // The Trainer stages everything once — dataset, partition grid,
    // engine, worker cluster — and streams records as they land.
    let mut trainer = Trainer::new(cfg)?;
    let ds = trainer.dataset();
    println!("dataset: {} ({} observations × {} features)", ds.name, ds.n(), ds.m());
    println!("engine:  {}", trainer.engine().name());
    println!("executor: {}\n", trainer.executor());

    println!("iter   F(w)      sim_s");
    let out = trainer.run_with_observer(|r| {
        println!("{:4}   {:.5}   {:.4}", r.iter, r.loss, r.sim_s);
        ControlFlow::Continue(())
    })?;
    println!(
        "\nF(0) = {:.4} → F(w_T) = {:.4}; {:.2} MB simulated communication",
        out.history.losses()[0],
        out.history.final_loss().unwrap(),
        out.comm_bytes as f64 / 1e6
    );
    Ok(())
}
