//! Sweep the paper's three sampling fractions (Figure 2 in miniature):
//! how (b^t, c^t, d^t) trade early speed against final accuracy.
//!
//! The whole sweep runs on **one** `Trainer` session: the dataset is
//! materialized, partitioned and staged once, and each variant just
//! `reconfigure`s the session — the API the figure harnesses use.
//!
//!     cargo run --release --example param_sweep

use sodda::{ExperimentConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let base = ExperimentConfig::builder()
        .name("sweep_base")
        .dense(3000, 240)
        .grid(5, 3)
        .seed(9)
        .build()?;

    let mut session = Trainer::new(base.clone())?;
    let ds = session.dataset();
    println!("sweep on {} ({} × {})\n", ds.name, ds.n(), ds.m());
    println!("{:<24} {:>10} {:>10} {:>12}", "fractions (b,c,d)", "F @ 10", "F @ 30", "coord-evals");

    let sweeps = [
        (1.00, 1.00, 1.00),
        (0.95, 0.80, 0.85),
        (0.85, 0.80, 0.85), // the paper's tuned setting
        (0.75, 0.60, 0.85),
        (0.65, 0.40, 0.60),
    ];
    for (b, c, d) in sweeps {
        session.reconfigure(
            base.to_builder()
                .name(format!("sweep_b{b}_c{c}_d{d}"))
                .fractions_bcd(b, c, d)
                .build()?,
        )?;
        let out = session.run()?;
        let at = |i: usize| out.history.records.iter().find(|r| r.iter == i).map(|r| r.loss).unwrap();
        println!(
            "({b:.2}, {c:.2}, {d:.2})       {:>10.4} {:>10.4} {:>12}",
            at(10),
            at(30),
            out.history.records.last().unwrap().grad_coord_evals
        );
    }
    println!("\nsmaller fractions → fewer coordinate evaluations (cheaper iterations),\nlarger fractions → better late-stage accuracy — Figure 2's trade-off.");
    Ok(())
}
