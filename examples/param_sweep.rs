//! Sweep the paper's three sampling fractions (Figure 2 in miniature):
//! how (b^t, c^t, d^t) trade early speed against final accuracy.
//!
//!     cargo run --release --example param_sweep

use std::sync::Arc;

use sodda::config::{AlgorithmKind, DataConfig, ExperimentConfig, SamplingFractions, Schedule};
use sodda::coordinator::train_with_engine;
use sodda::engine::NativeEngine;
use sodda::loss::Loss;

fn main() -> anyhow::Result<()> {
    let dc = DataConfig::Dense { n: 3000, m: 240 };
    let ds = dc.materialize(9);
    println!("sweep on {} ({} × {})\n", ds.name, ds.n(), ds.m());
    println!("{:<24} {:>10} {:>10} {:>12}", "fractions (b,c,d)", "F @ 10", "F @ 30", "coord-evals");

    let sweeps = [
        (1.00, 1.00, 1.00),
        (0.95, 0.80, 0.85),
        (0.85, 0.80, 0.85), // the paper's tuned setting
        (0.75, 0.60, 0.85),
        (0.65, 0.40, 0.60),
    ];
    for (b, c, d) in sweeps {
        let cfg = ExperimentConfig {
            name: format!("sweep_b{b}_c{c}_d{d}"),
            data: dc.clone(),
            p: 5,
            q: 3,
            loss: Loss::Hinge,
            algorithm: AlgorithmKind::Sodda,
            fractions: SamplingFractions { b, c, d },
            inner_steps: 32,
            outer_iters: 30,
            schedule: Schedule::ScaledSqrt { gamma0: 0.08 },
            seed: 9,
            engine: Default::default(),
            network: None,
            eval_every: 1,
        };
        let out = train_with_engine(&cfg, &ds, Arc::new(NativeEngine))?;
        let at = |i: usize| out.history.records.iter().find(|r| r.iter == i).map(|r| r.loss).unwrap();
        println!(
            "({b:.2}, {c:.2}, {d:.2})       {:>10.4} {:>10.4} {:>12}",
            at(10),
            at(30),
            out.history.records.last().unwrap().grad_coord_evals
        );
    }
    println!("\nsmaller fractions → fewer coordinate evaluations (cheaper iterations),\nlarger fractions → better late-stage accuracy — Figure 2's trade-off.");
    Ok(())
}
