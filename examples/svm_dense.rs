//! §5.1-style experiment: SODDA vs RADiSA vs RADiSA-avg on dense
//! synthetic SVM data (the Zhang et al. generator), reporting
//! time-to-loss — all three algorithms on **one** staged session, plus a
//! warm-started chained run (Nathan & Klabjan-style comparisons).
//!
//!     cargo run --release --example svm_dense -- --scale 100 --iters 25

use sodda::config::{preset, AlgorithmKind, ExperimentConfig};
use sodda::harness::time_to_loss_summary;
use sodda::util::cli::Args;
use sodda::Trainer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let scale = args.parse_or("scale", 0usize)?;
    let iters = args.parse_or("iters", 30usize)?;
    let pr = preset("small").unwrap();
    let dc = pr.data_config(if scale == 0 { pr.default_scale } else { scale }, 5, 3);

    let base = ExperimentConfig::builder()
        .name("svm_dense_base")
        .data(dc)
        .grid(5, 3)
        .outer_iters(iters)
        .seed(7)
        .build()?;

    let mut session = Trainer::new(base.clone())?;
    let ds = session.dataset();
    println!("dataset {} ({} × {})\n", ds.name, ds.n(), ds.m());

    let mut histories = Vec::new();
    let mut sodda_w: Vec<f32> = Vec::new();
    for algo in [AlgorithmKind::Sodda, AlgorithmKind::Radisa, AlgorithmKind::RadisaAvg] {
        session.reconfigure(
            base.to_builder().name(format!("svm_dense_{algo}")).algorithm(algo).build()?,
        )?;
        let out = session.run()?;
        println!(
            "{algo:<12} final F = {:.4}   simulated time {:.2}s",
            out.history.final_loss().unwrap(),
            out.history.records.last().unwrap().sim_s
        );
        if algo == AlgorithmKind::Sodda {
            sodda_w = out.w.clone();
        }
        histories.push(out.history);
    }

    println!("\ntime to reach loss targets (simulated seconds):");
    print!("{}", time_to_loss_summary(&histories[0], &histories[2]));

    // chained run: RADiSA-avg warm-started from SODDA's final iterate —
    // the session keeps its staged dataset/cluster, only ω^0 changes
    session.reconfigure(
        base.to_builder()
            .name("svm_dense_radisa-avg_warm")
            .algorithm(AlgorithmKind::RadisaAvg)
            .build()?,
    )?;
    session.warm_start(&sodda_w)?;
    let warm = session.run()?;
    println!(
        "\nwarm-started radisa-avg: F(ω^0) = {:.4} → F(ω^T) = {:.4}",
        warm.history.losses()[0],
        warm.history.final_loss().unwrap()
    );
    Ok(())
}
