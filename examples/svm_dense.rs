//! §5.1-style experiment: SODDA vs RADiSA vs RADiSA-avg on dense
//! synthetic SVM data (the Zhang et al. generator), reporting time-to-loss.
//!
//!     cargo run --release --example svm_dense -- --scale 100 --iters 25

use std::sync::Arc;

use sodda::config::{preset, AlgorithmKind, ExperimentConfig, SamplingFractions, Schedule};
use sodda::coordinator::train_with_engine;
use sodda::engine::NativeEngine;
use sodda::harness::time_to_loss_summary;
use sodda::loss::Loss;
use sodda::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let scale = args.parse_or("scale", 0usize)?;
    let iters = args.parse_or("iters", 30usize)?;
    let pr = preset("small").unwrap();
    let dc = pr.data_config(if scale == 0 { pr.default_scale } else { scale }, 5, 3);
    let ds = dc.materialize(7);
    println!("dataset {} ({} × {})\n", ds.name, ds.n(), ds.m());

    let mut histories = Vec::new();
    for algo in [AlgorithmKind::Sodda, AlgorithmKind::Radisa, AlgorithmKind::RadisaAvg] {
        let cfg = ExperimentConfig {
            name: format!("svm_dense_{algo}"),
            data: dc.clone(),
            p: 5,
            q: 3,
            loss: Loss::Hinge,
            algorithm: algo,
            fractions: SamplingFractions::PAPER,
            inner_steps: 32,
            outer_iters: iters,
            schedule: Schedule::ScaledSqrt { gamma0: 0.08 },
            seed: 7,
            engine: Default::default(),
            network: None,
            eval_every: 1,
        };
        let out = train_with_engine(&cfg, &ds, Arc::new(NativeEngine))?;
        println!(
            "{algo:<12} final F = {:.4}   simulated time {:.2}s",
            out.history.final_loss().unwrap(),
            out.history.records.last().unwrap().sim_s
        );
        histories.push(out.history);
    }

    println!("\ntime to reach loss targets (simulated seconds):");
    print!("{}", time_to_loss_summary(&histories[0], &histories[2]));
    Ok(())
}
