//! §5.2-style experiment: sparse CSR SVM (the SemMedDB/PRA substitute —
//! see DESIGN.md §Substitutions), SODDA vs RADiSA-avg.
//!
//!     cargo run --release --example svm_sparse -- --dataset loc-neg5

use std::sync::Arc;

use sodda::config::{preset, AlgorithmKind, ExperimentConfig, SamplingFractions, Schedule};
use sodda::coordinator::train_with_engine;
use sodda::engine::NativeEngine;
use sodda::loss::Loss;
use sodda::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let name = args.str_or("dataset", "diag-neg10");
    let pr = preset(&name).ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    anyhow::ensure!(pr.sparse, "{name} is not a sparse preset");
    let scale = args.parse_or("scale", 0usize)?;
    let dc = pr.data_config(if scale == 0 { pr.default_scale } else { scale }, 5, 3);
    let ds = dc.materialize(3);
    let density = ds.x.nnz() as f64 / (ds.n() as f64 * ds.m() as f64);
    println!(
        "dataset {name}: {} × {} CSR, {:.3}% dense, {:.1} nnz/row\n",
        ds.n(),
        ds.m(),
        100.0 * density,
        ds.x.nnz() as f64 / ds.n() as f64
    );

    for algo in [AlgorithmKind::Sodda, AlgorithmKind::RadisaAvg] {
        let cfg = ExperimentConfig {
            name: format!("svm_sparse_{algo}"),
            data: dc.clone(),
            p: 5,
            q: 3,
            loss: Loss::Hinge,
            algorithm: algo,
            fractions: SamplingFractions::PAPER,
            inner_steps: 32,
            outer_iters: args.parse_or("iters", 25usize)?,
            schedule: Schedule::ScaledSqrt { gamma0: 0.08 },
            seed: 3,
            engine: Default::default(),
            network: None,
            eval_every: 1,
        };
        let out = train_with_engine(&cfg, &ds, Arc::new(NativeEngine))?;
        println!("{algo:<12} loss curve:");
        for r in out.history.records.iter().step_by(5) {
            println!("   iter {:3}  F = {:.4}  sim {:.2}s", r.iter, r.loss, r.sim_s);
        }
        println!();
    }
    Ok(())
}
