//! §5.2-style experiment: sparse CSR SVM (the SemMedDB/PRA substitute —
//! see DESIGN.md §Substitutions), SODDA vs RADiSA-avg on one staged
//! session. Pass `--budget SECONDS` to cap each run at a simulated-time
//! deadline (the paper's early-iteration regime).
//!
//!     cargo run --release --example svm_sparse -- --dataset loc-neg5

use sodda::config::{preset, AlgorithmKind, ExperimentConfig};
use sodda::train::observers;
use sodda::util::cli::Args;
use sodda::Trainer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let name = args.str_or("dataset", "diag-neg10");
    let pr = preset(&name).ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    anyhow::ensure!(pr.sparse, "{name} is not a sparse preset");
    let scale = args.parse_or("scale", 0usize)?;
    let budget = args.parse_or("budget", f64::INFINITY)?;
    let dc = pr.data_config(if scale == 0 { pr.default_scale } else { scale }, 5, 3);

    let base = ExperimentConfig::builder()
        .name("svm_sparse_base")
        .data(dc)
        .grid(5, 3)
        .outer_iters(args.parse_or("iters", 25usize)?)
        .seed(3)
        .build()?;

    let mut session = Trainer::new(base.clone())?;
    let ds = session.dataset();
    let density = ds.x.nnz() as f64 / (ds.n() as f64 * ds.m() as f64);
    println!(
        "dataset {name}: {} × {} CSR, {:.3}% dense, {:.1} nnz/row\n",
        ds.n(),
        ds.m(),
        100.0 * density,
        ds.x.nnz() as f64 / ds.n() as f64
    );

    for algo in [AlgorithmKind::Sodda, AlgorithmKind::RadisaAvg] {
        session.reconfigure(
            base.to_builder().name(format!("svm_sparse_{algo}")).algorithm(algo).build()?,
        )?;
        let out = session.run_with_observer(observers::sim_deadline(budget))?;
        println!("{algo:<12} loss curve:");
        for r in out.history.records.iter().step_by(5) {
            println!("   iter {:3}  F = {:.4}  sim {:.2}s", r.iter, r.loss, r.sim_s);
        }
        if (out.history.records.last().unwrap().iter) < session.config().outer_iters {
            println!("   (stopped at the {budget}s simulated-time budget)");
        }
        println!();
    }
    Ok(())
}
